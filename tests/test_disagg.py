"""Disaggregated prefill/decode serving: the KV handoff must change WHERE
a request's phases run — prefill on one replica, decode on another, the
pages shipped between them over a modeled link — while the token stream
stays BIT-identical to colocated serving. Covers the page round-trip
(pool -> wire -> pool scatter, across different stage splits), end-to-end
identity (plain, warm-prefix, and mid-prefill-chunked), virtual-clock
transfer-cost accounting, decode-side capacity rejection, and the
scheduler's role-assignment search."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import slo_sim
from repro.core.genetic import best_role_split
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.core.slo_sim import PhasedReplicaModel
from repro.models import model as M
from repro.serving.block_manager import BlockPool, BlockTable, \
    blocks_for_tokens
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.disagg import KVLink, KVMigration, wire_disaggregation
from repro.serving.engine import InferenceEngine
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request, shared_prefix_workload

KEY = jax.random.PRNGKey(0)
BLOCK = 8
MAX_LEN = 48


# ---------------------------------------------------------------------------
# Shared model/pipelines (jit amortized across the module)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe(split=None):
        split = split if split is not None else [1, L - 1]
        return AsymmetricPipeline(cfg, params, split, [[dev]] * len(split))

    return cfg, pipe, L


def _mk_reqs(cfg, *, out_len=5, seed=3, n=6):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = 8 + int(rng.randint(0, 12))
        reqs.append(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab_size,
                                      size=plen).astype(np.int32),
            max_new_tokens=out_len, arrival=0.4 * i))
    return reqs


def _serve(workers, reqs, roles=None, link=None):
    if roles is not None:
        wire_disaggregation(workers, roles, link)
    return run_serve_loop(workers, reqs, deadline=1e9, clock=VirtualClock())


# ---------------------------------------------------------------------------
# Wire round-trip: pool -> extract -> scatter -> pool
# ---------------------------------------------------------------------------

def test_kv_page_roundtrip_across_stage_splits(setup):
    """Pages extracted from a [1, L-1] pipeline land bit-identically in a
    [L-1, 1] pipeline: the wire format is per-GLOBAL-layer, so regrouping
    layers across stages is just a different iteration order."""
    cfg, pipe, L = setup
    src, dst = pipe([1, L - 1]), pipe([L - 1, 1])
    src.init_paged_caches(2, MAX_LEN, block_size=BLOCK)
    dst.init_paged_caches(2, MAX_LEN, block_size=BLOCK)
    # poke recognizable values into three src blocks of every layer
    rng = np.random.RandomState(0)
    src_blocks = [3, 1, 4]
    for si, st in enumerate(src.stages):
        for k, c in enumerate(src.paged_caches[si]):
            for n in ("k", "v"):
                arr = np.array(c[n])
                arr[src_blocks] = rng.standard_normal(
                    (3,) + arr.shape[1:]).astype(arr.dtype)
                c[n] = jax.numpy.asarray(arr)
    payload = src.extract_kv_pages([src_blocks] * len(src.stages))
    assert len(payload) == L
    nbytes = KVMigration.payload_bytes(payload)
    assert nbytes == sum(a.nbytes for lkv in payload
                         for a in lkv.values())
    dst_blocks = [5, 2, 1]
    dst.scatter_kv_pages([dst_blocks] * len(dst.stages), payload)
    # reassemble dst per global layer and compare against the wire
    got = dst.extract_kv_pages([dst_blocks] * len(dst.stages))
    for lkv_want, lkv_got in zip(payload, got):
        for n in ("k", "v"):
            np.testing.assert_array_equal(lkv_want[n], lkv_got[n])


def test_block_table_adopt_takes_over_references():
    pool = BlockPool(6, block_size=4)
    donor = pool.alloc(2)
    t = BlockTable(pool)
    t.adopt(donor)
    assert t.blocks == donor and pool.n_free == 3
    t.release()
    assert pool.n_free == 5


# ---------------------------------------------------------------------------
# End-to-end identity: disaggregated == colocated token streams
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_colocated(setup):
    cfg, pipe, L = setup
    reqs = _mk_reqs(cfg)
    w = PagedPipelineBatcher(pipe(), n_slots=4, max_len=MAX_LEN,
                             block_size=BLOCK)
    _serve([w], reqs)
    assert all(r.output is not None and len(r.output) == r.max_new_tokens
               for r in reqs)
    return reqs


def test_disagg_bit_identical_to_colocated(setup, served_colocated):
    """Prefill on a [1, L-1] replica, decode on a [L-1, 1] replica (the
    stage splits deliberately differ): token streams must match colocated
    serving bit for bit, with every request migrated exactly once."""
    cfg, pipe, L = setup
    reqs = _mk_reqs(cfg)
    p = PagedPipelineBatcher(pipe([1, L - 1]), n_slots=4, max_len=MAX_LEN,
                             block_size=BLOCK, role="prefill")
    d = PagedPipelineBatcher(pipe([L - 1, 1]), n_slots=4, max_len=MAX_LEN,
                             block_size=BLOCK, role="decode")
    stats = _serve([p, d], reqs, roles=["prefill", "decode"], link=KVLink())
    for rc, rd in zip(served_colocated, reqs):
        assert list(rc.output) == list(rd.output), rc.rid
    assert stats.migrations == len(reqs)
    assert stats.migrated_kv_bytes > 0
    assert stats.rejected == 0 and stats.dropped == 0
    # the decode replica stamped first tokens; the prefill replica stamped
    # the handoffs, never a token
    assert all(r.first_token_time is not None
               and r.prefill_finish_time is not None
               and r.first_token_time >= r.prefill_finish_time
               for r in reqs)


def test_disagg_with_prefix_cache_and_chunking_identical(setup):
    """Warm-prefix + mid-prefill chunking on the PREFILL replica compose
    with the handoff: same tokens as cold colocated serving, with real
    prefix hits on the prefill side."""
    cfg, pipe, L = setup

    def wl():
        return shared_prefix_workload(
            rate=2.0, duration=4.0, vocab=cfg.vocab_size, shared_len=24,
            unique_len=6, out_len=5, seed=11)

    cold = wl()
    _serve([PagedPipelineBatcher(pipe(), n_slots=4, max_len=MAX_LEN,
                                 block_size=BLOCK)], cold)
    warm = wl()
    p = PagedPipelineBatcher(pipe(), n_slots=4, max_len=MAX_LEN,
                             block_size=BLOCK, role="prefill",
                             prefix_caching=True, prefill_chunk=BLOCK)
    d = PagedPipelineBatcher(pipe([L - 1, 1]), n_slots=4, max_len=MAX_LEN,
                             block_size=BLOCK, role="decode")
    stats = _serve([p, d], warm, roles=["prefill", "decode"], link=KVLink())
    for rc, rw in zip(cold, warm):
        assert list(rc.output) == list(rw.output), rc.rid
    assert stats.prefix_hits > 0
    assert stats.migrations == len(warm)


# ---------------------------------------------------------------------------
# Transfer-cost accounting on the virtual clock
# ---------------------------------------------------------------------------

def test_transfer_cost_delays_first_token_by_bytes_over_bandwidth(setup):
    cfg, pipe, L = setup

    def one():
        return [Request(rid=0, prompt=np.arange(16, dtype=np.int32),
                        max_new_tokens=4, arrival=0.0)]

    ttft, bytes_seen = {}, {}
    for gbps in (0.0, 1e-6):      # ideal link vs ~125 B per clock unit
        reqs = one()
        p = PagedPipelineBatcher(pipe(), n_slots=2, max_len=32,
                                 block_size=BLOCK, role="prefill")
        d = PagedPipelineBatcher(pipe(), n_slots=2, max_len=32,
                                 block_size=BLOCK, role="decode")
        st = _serve([p, d], reqs, roles=["prefill", "decode"],
                    link=KVLink(gbps=gbps))
        ttft[gbps] = reqs[0].first_token_time
        bytes_seen[gbps] = st.migrated_kv_bytes
    # payload size is exact: whole blocks of K and V for every layer
    nb = blocks_for_tokens(16, BLOCK)
    el = np.dtype(np.float32).itemsize
    want = nb * BLOCK * cfg.num_kv_heads * cfg.head_dim_ * el * 2 * L
    assert bytes_seen[0.0] == bytes_seen[1e-6] == want
    # and the finite link delays the first token by exactly bytes/bw on
    # the virtual clock (both runs pay the same prefill iterations)
    delay = want / (1e-6 * 1e9 / 8)
    assert ttft[1e-6] - ttft[0.0] == pytest.approx(delay, rel=1e-9)


def test_decode_replica_rejects_impossible_migration(setup):
    """A migration whose full generation can never fit the decode pools is
    rejected with an empty output instead of preempt-thrashing forever."""
    cfg, pipe, L = setup
    reqs = [Request(rid=0, prompt=np.arange(24, dtype=np.int32),
                    max_new_tokens=8, arrival=0.0)]
    p = PagedPipelineBatcher(pipe(), n_slots=2, max_len=MAX_LEN,
                             block_size=BLOCK, role="prefill")
    d = PagedPipelineBatcher(pipe(), n_slots=2, max_len=MAX_LEN,
                             block_size=BLOCK, role="decode",
                             stage_blocks=[3, 3])   # 2 usable blocks
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        stats = _serve([p, d], reqs, roles=["prefill", "decode"],
                       link=KVLink())
    assert stats.rejected == 1 and stats.migrations == 1
    assert not reqs[0].served and reqs[0].finish_time is not None


def test_kvlink_from_cluster_minimizes_latency_plus_transfer():
    """The per-pair link choice must minimize lat + bytes/bw PER PAYLOAD,
    like the scheduler's role search: a low-latency thin link wins small
    transfers, a fat high-latency link wins big ones."""
    from repro.core.cluster import Cluster, Device

    devs = [Device(0, "A6000", 0, "r0"), Device(1, "A6000", 1, "r0"),
            Device(2, "A6000", 2, "r0")]
    lat = np.zeros((3, 3))
    bw = np.full((3, 3), np.inf)
    # replica 0 = {0}; replica 1 = {1, 2}: two candidate links with
    # opposite strengths
    lat[0, 1] = lat[1, 0] = 1e-3; bw[0, 1] = bw[1, 0] = 1e6   # low lat, thin
    lat[0, 2] = lat[2, 0] = 1e-1; bw[0, 2] = bw[2, 0] = 1e12  # high lat, fat
    cluster = Cluster(devs, lat=lat, bw=bw)
    link = KVLink.from_cluster(cluster, [[0], [1, 2]])
    small, big = 100, 10 ** 9
    assert link.delay(small, 0, 1) == pytest.approx(1e-3 + small / 1e6)
    assert link.delay(big, 0, 1) == pytest.approx(1e-1 + big / 1e12)
    # never worse than either single link
    for n in (small, big, 10 ** 6):
        assert link.delay(n, 0, 1) <= min(1e-3 + n / 1e6, 1e-1 + n / 1e12)


# ---------------------------------------------------------------------------
# Router / engine gating
# ---------------------------------------------------------------------------

def test_engine_roles_and_gating(setup):
    cfg, pipe, L = setup
    asg = Assignment([
        PipelinePlan([StagePlan([0], 1), StagePlan([1], L - 1)], 0.1, 0.1),
        PipelinePlan([StagePlan([2], L)], 0.1, 0.1),
    ])
    eng = InferenceEngine(cfg, asg, key=KEY, policy="continuous",
                          n_slots=4, max_len=MAX_LEN, cache_layout="paged",
                          block_size=BLOCK, disaggregate=True)
    assert eng.roles.count("prefill") == 1
    assert eng.roles.count("decode") == 1
    # contiguous layout cannot ship pages: falls back to colocated, loudly
    with warnings.catch_warnings(record=True) as ws:
        warnings.simplefilter("always")
        eng_c = InferenceEngine(cfg, asg, key=KEY, policy="continuous",
                                n_slots=4, max_len=MAX_LEN,
                                cache_layout="contiguous",
                                disaggregate=True)
    assert eng_c.roles == ["both", "both"]
    assert any("colocated" in str(w.message) for w in ws)


# ---------------------------------------------------------------------------
# Scheduler: role assignment as a search dimension
# ---------------------------------------------------------------------------

def test_role_split_matches_workload_shape():
    """Decode-heavy workloads want decode-majority splits; prefill-heavy
    ones shift replicas back toward prefill."""
    decode_heavy = [PhasedReplicaModel(0.1, 0.1, 0.8, 0.8)
                    for _ in range(4)]
    roles_d, att_d = best_role_split(decode_heavy, rate=3.0, deadline=2.5,
                                     duration=60.0)
    assert roles_d.count("decode") > roles_d.count("prefill")
    prefill_heavy = [PhasedReplicaModel(0.8, 0.8, 0.1, 0.1)
                     for _ in range(4)]
    roles_p, att_p = best_role_split(prefill_heavy, rate=3.0, deadline=2.5,
                                     duration=60.0)
    assert roles_p.count("prefill") >= roles_d.count("prefill")
    assert att_d > 0 and att_p > 0


def test_role_split_beats_colocated_on_heterogeneous_pool():
    """The HexGen-2 case: one compute-rich replica (fast prefill) + one
    memory-rich replica (slow prefill, deep decode queue). Colocated
    serving drags half the arrivals through the slow prefill; the split
    routes every prefill to the fast replica and wins attainment — even
    paying a real transfer cost."""
    models = [PhasedReplicaModel(0.2, 0.2, 1.0, 0.5, max_concurrent=4),
              PhasedReplicaModel(3.0, 3.0, 1.0, 0.25, max_concurrent=64)]
    col = slo_sim.simulate([m.colocated() for m in models], 1.5, 4.0,
                           duration=60.0)
    roles, att = best_role_split(models, rate=1.5, deadline=4.0,
                                 duration=60.0, kv_bytes=1e6, link_bw=1e9)
    assert roles == ["prefill", "decode"]
    assert att > col


def test_simulate_disagg_all_both_equals_simulate():
    models = [PhasedReplicaModel(0.2, 0.1, 0.6, 0.3, max_concurrent=8)
              for _ in range(2)]
    a = slo_sim.simulate([m.colocated() for m in models], 2.0, 3.0,
                         duration=40.0, seed=1)
    b = slo_sim.simulate_disagg(models, ["both", "both"], 2.0, 3.0,
                                duration=40.0, seed=1)
    assert a == b
