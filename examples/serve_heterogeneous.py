"""End-to-end serving driver: schedule the paper's half-price heterogeneous
pool, stand up the multi-replica asymmetric-pipeline engine, and serve a
timed Poisson workload, reporting measured SLO attainment.

  PYTHONPATH=src python examples/serve_heterogeneous.py
"""
import subprocess
import sys

# the serving driver is a proper module CLI; this example drives it the way
# an operator would
subprocess.run([
    sys.executable, "-m", "repro.launch.serve",
    "--arch", "h2o-danube-1.8b", "--reduced",
    "--cluster", "half_price",
    "--rate", "3", "--duration", "4", "--deadline", "30",
    "--prompt-len", "16", "--out-len", "6", "--search-iters", "6",
], check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                    **__import__("os").environ})
