"""Quickstart: schedule a heterogeneous pool with HexGen's two-phase search,
then generate tokens through the asymmetric pipeline engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.scheduler import schedule
from repro.launch.serve import scale_assignment
from repro.serving.engine import InferenceEngine

# 1. the paper's case-study pool: 4xA6000 + 2xA5000 + 2xA4000
pool = cl.case_study_cluster()
print(f"pool: {len(pool)} GPUs, ${pool.price_per_hour:.2f}/h")

# 2. schedule LLAMA-2 (70B) service over it (cost model + DP + genetic)
task = cm.Task(batch=1, s_in=128, s_out=64)
res = schedule(pool, "llama2-70b", task, deadline=20.0, rate=0.5,
               iters=8, seed=0, paper_exact=True)
print(f"assignment: {res.assignment.describe()}")
print(f"estimated SLO attainment: {res.attainment*100:.0f}%")

# 3. execute the scheduled layout with a reduced model (CPU demo):
#    same stage structure, same TP degrees, fewer/smaller layers
cfg_full = get_config("llama2-70b")
cfg = cfg_full.reduced()
asg = scale_assignment(res.assignment, cfg_full.num_layers, cfg.num_layers)
engine = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0))

prompts = [np.arange(5, 13, dtype=np.int32),
           np.arange(40, 52, dtype=np.int32)]
outs = engine.generate(prompts, max_new=8)
for p, o in zip(prompts, outs):
    print(f"prompt[{len(p)} toks] -> {o.tolist()}")
print("OK")
