"""Explore the scheduler: search convergence on the full-price pool, the
layouts it discovers, and what-if pricing (half budget, TPU slices).

  PYTHONPATH=src python examples/schedule_explore.py
"""
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.scheduler import schedule

task = cm.Task(batch=1, s_in=128, s_out=32)

for name, pool, rate in (
        ("homogeneous 16xA100 ($65.54/h)", cl.homogeneous_a100(), 6.0),
        ("hetero full-price 58 GPUs ($65/h)", cl.hetero_full_price(), 6.0),
        ("hetero half-price 30 GPUs ($30/h)", cl.hetero_half_price(), 6.0),
        ("mixed TPU v5e slices (beyond-paper)", cl.tpu_mixed_slices(), 2.0)):
    res = schedule(pool, "llama2-70b", task, deadline=10.0, rate=rate,
                   iters=15, seed=0, paper_exact=True)
    print(f"\n== {name} ==")
    print(f"  replicas: {res.assignment.num_replicas}  "
          f"attainment@{rate}req/s: {res.attainment*100:.0f}%  "
          f"search evals: {res.evaluations}")
    for p in res.assignment.pipelines:
        print(f"    {p.describe()}  latency={p.cost:.2f}s "
              f"bottleneck={p.bottleneck:.2f}s")
