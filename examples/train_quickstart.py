"""Train a ~100M-param model for a few hundred steps on the synthetic
Markov stream, with checkpointing. (xlstm-125m full config, CPU-feasible.)

  PYTHONPATH=src python examples/train_quickstart.py [--steps 300]
"""
import subprocess
import sys
import os

steps = "300" if "--steps" not in sys.argv else \
    sys.argv[sys.argv.index("--steps") + 1]
subprocess.run([
    sys.executable, "-m", "repro.launch.train",
    "--arch", "xlstm-125m",
    "--steps", steps, "--batch", "4", "--seq", "128",
    "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_ckpt",
    "--ckpt-every", "100", "--log-every", "20",
], check=True, env={"PYTHONPATH": "src", **os.environ})
