#!/usr/bin/env bash
# CI entry point: tier-1 tests + a multi-device serving smoke.
#
# The smoke runs the continuous-batching serve path on an asymmetric
# pipeline with real tensor-parallel stages over 4 virtual host devices —
# the configuration a GPU-less CI would otherwise never execute.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== tier-1 pytest ==="
# deliberately the exact command ROADMAP.md names as the tier-1 gate
# (includes @slow; deselect locally with -m "not slow" for a fast loop)
python -m pytest -x -q

echo "=== serving smoke (4 virtual devices, ~30s) ==="
XLA_FLAGS="--xla_force_host_platform_device_count=4" JAX_PLATFORMS=cpu \
python - <<'PY'
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.serving.engine import InferenceEngine
from repro.serving.request import synth_workload

t0 = time.monotonic()
devs = jax.devices()
assert len(devs) == 4, devs
cfg = get_config("granite-8b").reduced()
L = cfg.num_layers
# a TP=2 -> TP=2 two-stage asymmetric pipeline over all 4 devices —
# the multi-device path a GPU-less CI would otherwise never run
asg = Assignment([
    PipelinePlan([StagePlan([0, 1], 1), StagePlan([2, 3], L - 1)],
                 cost=0.1, bottleneck=0.1),
])
eng = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                      policy="continuous", n_slots=4, max_len=48)
reqs = synth_workload(rate=40.0, duration=0.25, vocab=cfg.vocab_size,
                      prompt_len=8, prompt_jitter=5, out_len=4, seed=1)
stats = eng.serve(reqs, deadline=120.0)
assert len(stats.latencies) == len(reqs) and len(reqs) > 0
assert stats.attainment == 1.0, stats.summary()
for r in reqs:
    assert r.output is not None and len(r.output) == 4, r.rid
print(f"smoke OK: {stats.summary()} ({time.monotonic()-t0:.1f}s)")
PY
echo "=== ci.sh OK ==="
