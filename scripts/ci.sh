#!/usr/bin/env bash
# Tiered CI entry point (run by .github/workflows/ci.yml, and locally):
#
#   scripts/ci.sh --fast   fast gate: repro-lint + pytest -m "not slow" +
#                          interpret-mode kernel smoke (decode/context/
#                          verify) + the spec==greedy smoke + the
#                          quantized-KV smoke (fused-dequant kernels +
#                          int8-pool serving) + the tiered cluster-prefix
#                          smoke + the observability (HexTrace) smoke +
#                          the KVSAN serving smoke
#                          (~5 min on a laptop CPU)
#   scripts/ci.sh --full   everything: full pytest (incl. @slow multi-device
#                          subprocess sweeps), every serving smoke on 4
#                          virtual devices (continuous/paged/prefix/disagg/
#                          spec) plus the whole set again under the KVSAN
#                          lifecycle sanitizer, the launch.serve --trace-out
#                          smoke gated by the repro.obs.report CLI, and the
#                          benchmark-results + oracle-registry schema guard
#
# No flag defaults to --full (the historical behavior). The smokes
# themselves live in scripts/smoke_serving.py so humans can run or debug
# one suite directly without replaying the whole gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

TIER="${1:---full}"
case "$TIER" in
  --fast|--full) ;;
  *) echo "usage: $0 [--fast|--full]" >&2; exit 2 ;;
esac

echo "=== repro-lint (repo-specific static analysis) ==="
# pure-AST pass: clock discipline, jit-retrace hazards, kernel/oracle
# registry coverage, refcount pairing, hygiene — seconds, so every tier
python -m repro.analysis.lint src

if [[ "$TIER" == "--fast" ]]; then
  echo "=== tier-1 pytest (fast: -m 'not slow') ==="
  python -m pytest -x -q -m "not slow"
else
  echo "=== tier-1 pytest (full) ==="
  # deliberately the exact command ROADMAP.md names as the tier-1 gate
  python -m pytest -x -q
fi

echo "=== paged-attention kernels (Pallas interpret mode) ==="
# the paged decode + context-prefill + multi-token verification kernels
# with the Pallas backend engaged in interpret mode (GPU-less CI's only
# route through the block-table index maps); ops.backend() restores the
# global on error
python scripts/smoke_serving.py kernels

echo "=== speculative-decoding smoke (4 virtual devices) ==="
# spec == greedy token identity on the multi-device pipeline gates every
# tier: speculation must never change WHICH tokens serving produces
python scripts/smoke_serving.py spec

echo "=== quantized-KV smoke (interpret kernels + int8-pool serving) ==="
# the exactness gate for fused dequant (bitwise vs the unquantized
# kernels on materialized-dequant pages) plus int8 page pools end to end
python scripts/smoke_serving.py quant

echo "=== tiered cluster-prefix smoke (2 replicas, 4 virtual devices) ==="
# host-tier spill + shared-directory fetch + prefix-aware routing must
# stay token-identical to cold paged serving in every tier
python scripts/smoke_serving.py cluster

echo "=== observability smoke (HexTrace spans + metrics + report CLI) ==="
# a traced + metered serve must reproduce the untraced run token for
# token, and its Chrome-trace/metrics exports must pass the report CLI's
# schema gate — tracing is pure observation in every tier
python scripts/smoke_serving.py obs

if [[ "$TIER" == "--fast" ]]; then
  echo "=== KVSAN serving + chaos smoke (page-lifecycle sanitizer) ==="
  # the paged + prefix suites again under KVSAN, plus the online-
  # rescheduling chaos suite: a replica kill mid-request and a live role
  # migration mid-decode must stay token-identical to the cold runs with
  # zero page leaks through evacuation and migration
  python scripts/smoke_serving.py serving prefix chaos --kvsan
fi

if [[ "$TIER" == "--full" ]]; then
  echo "=== serving smokes (4 virtual devices) ==="
  python scripts/smoke_serving.py serving prefix disagg chaos

  echo "=== KVSAN serving smokes (page-lifecycle sanitizer) ==="
  # every serving suite again with the sanitizer shadowing the pools
  python scripts/smoke_serving.py serving prefix disagg cluster spec quant \
    obs chaos --kvsan

  echo "=== trace smoke (launch.serve --trace-out -> report CLI gate) ==="
  # the full CLI spine with tracing on: serve, export a Chrome trace +
  # metrics JSONL + the predicted-vs-observed calibration table, then
  # gate the artifacts on the report CLI's schema validation
  TRACE_TMP="$(mktemp -d)"
  trap 'rm -rf "$TRACE_TMP"' EXIT
  python -m repro.launch.serve --arch granite-8b --reduced \
    --cluster case_study --rate 4 --duration 1 --deadline 60 \
    --out-len 4 --search-iters 2 --policy continuous \
    --cache-layout paged --block-size 8 \
    --trace-out "$TRACE_TMP/trace.json" \
    --metrics-out "$TRACE_TMP/metrics.jsonl" --calibrate
  python -m repro.obs.report "$TRACE_TMP/metrics.jsonl" \
    --trace "$TRACE_TMP/trace.json" \
    --require-spans serve,queue_wait,iteration,prefill,decode

  echo "=== benchmark results + oracle registry schema guard ==="
  python -m benchmarks.run --check
fi

echo "=== ci.sh $TIER OK ==="
