#!/usr/bin/env bash
# CI entry point: tier-1 tests + a multi-device serving smoke.
#
# The smoke runs the continuous-batching serve path on an asymmetric
# pipeline with real tensor-parallel stages over 4 virtual host devices —
# the configuration a GPU-less CI would otherwise never execute.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== tier-1 pytest ==="
# deliberately the exact command ROADMAP.md names as the tier-1 gate
# (includes @slow; deselect locally with -m "not slow" for a fast loop)
python -m pytest -x -q

echo "=== paged-attention kernels (Pallas interpret mode) ==="
# the paged decode + context-prefill kernels with the Pallas backend
# engaged in interpret mode (GPU-less CI's only route through the
# block-table index maps)
python - <<'PY'
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import model as M

key = jax.random.PRNGKey(0)
b, hq, hkv, d, bs, nblk, nb = 2, 4, 2, 32, 16, 12, 4
rn = lambda i, *s: jax.random.normal(jax.random.fold_in(key, i), s)
q, kp, vp = rn(1, b, 1, hq, d), rn(2, nblk, bs, hkv, d), rn(3, nblk, bs, hkv, d)
bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6]], np.int32))
kv_len = jnp.array([41, 64])
qc = rn(4, b, 8, hq, d)                      # 8-token context chunk
q_start = jnp.array([17, 40])
ctx_len = jnp.array([17 + 8, 40 + 5])
ops.set_backend("pallas_interpret")
try:
    out = ops.paged_decode_attention(q, kp, vp, bt, kv_len=kv_len)
    out_c = ops.paged_context_attention(qc, kp, vp, bt, q_start=q_start,
                                        kv_len=ctx_len)
finally:
    ops.set_backend("xla")
want = ref.paged_decode_attention_ref(q, kp, vp, bt, kv_len=kv_len)
np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
want_c = ref.paged_context_attention_ref(qc, kp, vp, bt, q_start=q_start,
                                         kv_len=ctx_len)
np.testing.assert_allclose(np.asarray(out_c), np.asarray(want_c), atol=2e-5)
print("paged decode + context kernels interpret-mode OK")
PY

echo "=== serving smoke (4 virtual devices, ~30s) ==="
XLA_FLAGS="--xla_force_host_platform_device_count=4" JAX_PLATFORMS=cpu \
python - <<'PY'
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.serving.engine import InferenceEngine
from repro.serving.request import synth_workload

t0 = time.monotonic()
devs = jax.devices()
assert len(devs) == 4, devs
cfg = get_config("granite-8b").reduced()
L = cfg.num_layers
# a TP=2 -> TP=2 two-stage asymmetric pipeline over all 4 devices —
# the multi-device path a GPU-less CI would otherwise never run
asg = Assignment([
    PipelinePlan([StagePlan([0, 1], 1), StagePlan([2, 3], L - 1)],
                 cost=0.1, bottleneck=0.1),
])
eng = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                      policy="continuous", n_slots=4, max_len=48)
reqs = synth_workload(rate=40.0, duration=0.25, vocab=cfg.vocab_size,
                      prompt_len=8, prompt_jitter=5, out_len=4, seed=1)
stats = eng.serve(reqs, deadline=120.0)
assert len(stats.latencies) == len(reqs) and len(reqs) > 0
assert stats.attainment == 1.0, stats.summary()
for r in reqs:
    assert r.output is not None and len(r.output) == 4, r.rid
print(f"smoke OK: {stats.summary()} ({time.monotonic()-t0:.1f}s)")

# paged serving over the same 4-device asymmetric pipeline: per-stage
# block pools, identical outputs to the contiguous pass above
eng_p = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                        policy="continuous", n_slots=4, max_len=48,
                        cache_layout="paged", block_size=8)
reqs_p = synth_workload(rate=40.0, duration=0.25, vocab=cfg.vocab_size,
                        prompt_len=8, prompt_jitter=5, out_len=4, seed=1)
stats_p = eng_p.serve(reqs_p, deadline=120.0)
assert stats_p.attainment == 1.0, stats_p.summary()
for r, rp in zip(reqs, reqs_p):
    assert list(r.output) == list(rp.output), (r.rid, r.output, rp.output)
print(f"paged smoke OK: {stats_p.summary()} ({time.monotonic()-t0:.1f}s)")

# prefix-cache smoke: a shared-system-prompt workload served twice on the
# paged engine — cold, then with copy-on-write prefix caching + chunked
# prefill; tokens must match and the cache must actually hit
from repro.serving.request import shared_prefix_workload

def wl():
    return shared_prefix_workload(rate=4.0, duration=2.0,
                                  vocab=cfg.vocab_size, shared_len=24,
                                  unique_len=6, out_len=4, seed=3)

eng_c = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                        policy="continuous", n_slots=4, max_len=48,
                        cache_layout="paged", block_size=8)
reqs_cold = wl()
eng_c.serve(reqs_cold, deadline=120.0)
eng_w = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                        policy="continuous", n_slots=4, max_len=48,
                        cache_layout="paged", block_size=8,
                        prefix_caching=True, prefill_chunk=16)
reqs_warm = wl()
stats_w = eng_w.serve(reqs_warm, deadline=120.0)
assert stats_w.prefix_hits > 0, stats_w.summary()
assert stats_w.prefill_tokens < sum(len(r.prompt) for r in reqs_warm)
for rc, rw in zip(reqs_cold, reqs_warm):
    assert list(rc.output) == list(rw.output), (rc.rid,)
print(f"prefix smoke OK: {stats_w.summary()} ({time.monotonic()-t0:.1f}s)")
PY
echo "=== ci.sh OK ==="
