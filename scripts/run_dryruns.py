#!/usr/bin/env python
"""Sweep driver: run every (arch x shape x mesh) dry-run in a subprocess
(each needs a fresh jax with 512 host devices) and collect JSON records.

  PYTHONPATH=src python scripts/run_dryruns.py [--out results/dryrun]
      [--archs a,b,c] [--shapes s1,s2] [--mesh single|multi|both] [--skip-done]
"""
import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["granite-8b", "jamba-v0.1-52b", "h2o-danube-1.8b",
         "granite-moe-3b-a800m", "granite-20b", "xlstm-125m",
         "paligemma-3b", "codeqwen1.5-7b", "phi3.5-moe-42b-a6.6b",
         "whisper-base"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--timeout", type=int, default=1200)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    jobs = [(a, s, mp) for a in args.archs.split(",")
            for s in args.shapes.split(",") for mp in meshes]
    t0 = time.time()
    fails = []
    for i, (arch, shape, mp) in enumerate(jobs):
        tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
        out_json = os.path.join(args.out, tag + ".json")
        if args.skip_done and os.path.exists(out_json):
            print(f"[{i+1}/{len(jobs)}] {tag}: cached")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--json", out_json]
        if mp:
            cmd.append("--multi-pod")
        t1 = time.time()
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            ok = p.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            p = None
        dt = time.time() - t1
        if not ok:
            fails.append(tag)
            err = (p.stderr[-2000:] if p else "TIMEOUT")
            with open(os.path.join(args.out, tag + ".err"), "w") as f:
                f.write(err)
            print(f"[{i+1}/{len(jobs)}] {tag}: FAIL ({dt:.0f}s)")
        else:
            print(f"[{i+1}/{len(jobs)}] {tag}: ok ({dt:.0f}s)")
    print(f"done in {(time.time()-t0)/60:.1f} min; {len(fails)} failures")
    for f in fails:
        print("  FAIL:", f)


if __name__ == "__main__":
    main()
