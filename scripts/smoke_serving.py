#!/usr/bin/env python
"""Serving smokes, runnable by CI (scripts/ci.sh) and humans alike:

  PYTHONPATH=src python scripts/smoke_serving.py                 # everything
  PYTHONPATH=src python scripts/smoke_serving.py kernels         # one suite
  PYTHONPATH=src python scripts/smoke_serving.py serving disagg  # a subset

Suites:
  kernels  paged decode + context-prefill + multi-token verification
           Pallas kernels in interpret mode (a GPU-less CI's only route
           through the block-table index maps)
  serving  continuous + paged serving on a 2-stage TP=2 asymmetric pipeline
           over 4 virtual host devices, paged bit-identical to contiguous
  prefix   copy-on-write prefix caching + chunked prefill, warm == cold
  disagg   disaggregated prefill/decode with KV-page handoff, token-
           identical to colocated serving on the same 4-device pipeline
  cluster  host-tier page spill + shared prefix directory across two
           replicas: demotions/promotions/peer fetches on the virtual
           clock, token-identical to cold paged serving
  spec     speculative decoding (n-gram + self-draft proposers), token-
           identical to plain greedy decode on the same 4-device pipeline
           with strictly fewer target decode steps
  quant    quantized KV pages: int8/fp8 fused-dequant paged kernels in
           interpret mode (bitwise vs the unquantized kernels on
           materialized-dequant pages, tolerance vs the pure-JAX quant
           oracles), then int8-pool serving on the 4-device pipeline
           (greedy tokens vs fp32, resident-byte savings reported)
  obs      HexTrace observability: a traced + metered serve reproduces the
           untraced one token for token, and the exported Chrome trace +
           metrics JSONL pass the report CLI's schema gate

Each suite asserts hard invariants and prints one OK line; any failure is
a non-zero exit. The multi-device suites force 4 virtual CPU devices
themselves, so no XLA_FLAGS incantation is needed.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import time

# must happen before jax import: 4 virtual host devices, CPU only — an
# inherited count from the caller's shell is OVERRIDDEN, not trusted, so
# the suites' `len(devices) == 4` contract always holds
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = \
    (_flags + " --xla_force_host_platform_device_count=4").strip()

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

T0 = time.monotonic()
KVSAN = False     # --kvsan: serve every suite under the lifecycle sanitizer


def _ok(msg: str) -> None:
    print(f"smoke OK [{time.monotonic() - T0:5.1f}s] {msg}", flush=True)


# ---------------------------------------------------------------------------
# Suite: kernels (Pallas interpret mode)
# ---------------------------------------------------------------------------

def suite_kernels() -> None:
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    b, hq, hkv, d, bs, nblk = 2, 4, 2, 32, 16, 12
    rn = lambda i, *s: jax.random.normal(jax.random.fold_in(key, i), s)  # noqa: E731
    q, kp, vp = (rn(1, b, 1, hq, d), rn(2, nblk, bs, hkv, d),
                 rn(3, nblk, bs, hkv, d))
    bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6]], np.int32))
    kv_len = jnp.array([41, 64])
    qc = rn(4, b, 8, hq, d)                  # 8-token context chunk
    q_start = jnp.array([17, 40])
    ctx_len = jnp.array([17 + 8, 40 + 5])
    qv = rn(7, b, 4, hq, d)                  # 4-candidate verification chunk
    v_start = jnp.array([21, 33])
    v_len = jnp.array([21 + 4, 33 + 2])      # ragged candidate counts
    with ops.backend("pallas_interpret"):
        out = ops.paged_decode_attention(q, kp, vp, bt, kv_len=kv_len)
        out_c = ops.paged_context_attention(qc, kp, vp, bt,
                                            q_start=q_start, kv_len=ctx_len)
        out_v = ops.paged_verify_attention(qv, kp, vp, bt,
                                           kv_start=v_start, kv_len=v_len)
    assert ops.get_backend() == "xla", "backend leaked out of the context"
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    want_c = ref.paged_context_attention_ref(qc, kp, vp, bt,
                                             q_start=q_start, kv_len=ctx_len)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(want_c),
                               atol=2e-5)
    want_v = ref.paged_verify_attention_ref(qv, kp, vp, bt,
                                            kv_start=v_start, kv_len=v_len)
    np.testing.assert_allclose(np.asarray(out_v), np.asarray(want_v),
                               atol=2e-5)
    _ok("paged decode + context + verify kernels (interpret mode)")


# ---------------------------------------------------------------------------
# Shared serving scaffolding (4 virtual devices)
# ---------------------------------------------------------------------------

def _setup():
    from repro.configs import get_config
    from repro.core.plan import Assignment, PipelinePlan, StagePlan

    devs = jax.devices()
    assert len(devs) == 4, devs
    cfg = get_config("granite-8b").reduced()
    L = cfg.num_layers
    # a TP=2 -> TP=2 two-stage asymmetric pipeline over all 4 devices —
    # the multi-device path a GPU-less CI would otherwise never run
    asg = Assignment([
        PipelinePlan([StagePlan([0, 1], 1), StagePlan([2, 3], L - 1)],
                     cost=0.1, bottleneck=0.1),
    ])
    return cfg, asg


def _engine(cfg, asg, **kw):
    from repro.serving.engine import InferenceEngine
    kw.setdefault("kvsan", KVSAN)
    eng = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                          policy="continuous", n_slots=4, max_len=48, **kw)
    if kw["kvsan"]:
        # under --kvsan every serve must come back leak-free; violations
        # raise KVSanViolation mid-serve on their own
        inner = eng.serve

        def serve(reqs, **skw):
            stats = inner(reqs, **skw)
            assert stats.kvsan_leaks == 0, stats.summary()
            return stats
        eng.serve = serve
    return eng


def suite_serving() -> None:
    from repro.serving.request import synth_workload

    cfg, asg = _setup()
    reqs = synth_workload(rate=40.0, duration=0.25, vocab=cfg.vocab_size,
                          prompt_len=8, prompt_jitter=5, out_len=4, seed=1)
    stats = _engine(cfg, asg).serve(reqs, deadline=120.0)
    assert len(stats.latencies) == len(reqs) and len(reqs) > 0
    assert stats.attainment == 1.0, stats.summary()
    for r in reqs:
        assert r.output is not None and len(r.output) == 4, r.rid
    _ok(f"continuous serving: {stats.summary()}")

    # paged serving over the same pipeline: per-stage block pools,
    # identical outputs to the contiguous pass above
    reqs_p = synth_workload(rate=40.0, duration=0.25, vocab=cfg.vocab_size,
                            prompt_len=8, prompt_jitter=5, out_len=4, seed=1)
    stats_p = _engine(cfg, asg, cache_layout="paged",
                      block_size=8).serve(reqs_p, deadline=120.0)
    assert stats_p.attainment == 1.0, stats_p.summary()
    for r, rp in zip(reqs, reqs_p):
        assert list(r.output) == list(rp.output), (r.rid,)
    _ok(f"paged == contiguous: {stats_p.summary()}")


def suite_prefix() -> None:
    from repro.serving.request import shared_prefix_workload

    cfg, asg = _setup()

    def wl():
        return shared_prefix_workload(rate=4.0, duration=2.0,
                                      vocab=cfg.vocab_size, shared_len=24,
                                      unique_len=6, out_len=4, seed=3)

    reqs_cold = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_cold, deadline=120.0)
    reqs_warm = wl()
    stats_w = _engine(cfg, asg, cache_layout="paged", block_size=8,
                      prefix_caching=True,
                      prefill_chunk=16).serve(reqs_warm, deadline=120.0)
    assert stats_w.prefix_hits > 0, stats_w.summary()
    assert stats_w.prefill_tokens < sum(len(r.prompt) for r in reqs_warm)
    for rc, rw in zip(reqs_cold, reqs_warm):
        assert list(rc.output) == list(rw.output), (rc.rid,)
    _ok(f"prefix caching warm == cold: {stats_w.summary()}")


def suite_disagg() -> None:
    from repro.configs import get_config
    from repro.core.plan import Assignment, PipelinePlan, StagePlan
    from repro.serving.loop import VirtualClock
    from repro.serving.request import synth_workload

    cfg = get_config("granite-8b").reduced()
    L = cfg.num_layers
    # two replicas over the 4 devices, with DIFFERENT stage splits: the
    # prefill->decode page handoff must survive layer regrouping
    asg = Assignment([
        PipelinePlan([StagePlan([0], 1), StagePlan([1], L - 1)],
                     cost=0.1, bottleneck=0.1),
        PipelinePlan([StagePlan([2], L - 1), StagePlan([3], 1)],
                     cost=0.1, bottleneck=0.1),
    ])

    def wl():
        return synth_workload(rate=10.0, duration=1.0, vocab=cfg.vocab_size,
                              prompt_len=10, prompt_jitter=5, out_len=4,
                              seed=2)

    reqs_c = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_c, deadline=1e9, clock=VirtualClock())
    reqs_d = wl()
    stats_d = _engine(cfg, asg, cache_layout="paged", block_size=8,
                      disaggregate=True).serve(reqs_d, deadline=1e9,
                                               clock=VirtualClock())
    assert stats_d.migrations == len(reqs_d), stats_d.summary()
    assert stats_d.migrated_kv_bytes > 0
    for rc, rd in zip(reqs_c, reqs_d):
        assert list(rc.output) == list(rd.output), (rc.rid,)
    _ok(f"disaggregated == colocated: {stats_d.summary()}")


def suite_cluster() -> None:
    from repro.configs import get_config
    from repro.core.plan import Assignment, PipelinePlan, StagePlan
    from repro.serving.loop import VirtualClock
    from repro.serving.request import shared_prefix_workload

    cfg = get_config("granite-8b").reduced()
    L = cfg.num_layers
    # two replicas over the 4 devices: the shared prefix directory must
    # route revisits across them and fetch peer-resident pages
    asg = Assignment([
        PipelinePlan([StagePlan([0], 1), StagePlan([1], L - 1)],
                     cost=0.1, bottleneck=0.1),
        PipelinePlan([StagePlan([2], 1), StagePlan([3], L - 1)],
                     cost=0.1, bottleneck=0.1),
    ])

    def wl():
        return shared_prefix_workload(rate=6.0, duration=2.0,
                                      vocab=cfg.vocab_size, shared_len=24,
                                      unique_len=6, out_len=4, seed=7)

    reqs_c = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_c, deadline=1e9, clock=VirtualClock())
    # tiered + clustered: pools too small for the shared set, so hot
    # heads demote to the host tier and come back via promotion or a
    # peer fetch instead of a re-prefill
    reqs_t = wl()
    stats_t = _engine(cfg, asg, cache_layout="paged", block_size=8,
                      stage_blocks=[8, 8], prefix_caching=True,
                      host_blocks=32, host_swap_cost=0.01,
                      cluster_prefix=True, prefix_route_weight=0.5,
                      prefill_token_cost=0.125).serve(
                          reqs_t, deadline=1e9, clock=VirtualClock())
    assert stats_t.host_demotions > 0, stats_t.summary()
    assert stats_t.host_promotions + stats_t.prefix_fetches > 0, \
        stats_t.summary()
    assert stats_t.prefill_tokens < sum(len(r.prompt) for r in reqs_t)
    for rc, rt in zip(reqs_c, reqs_t):
        assert list(rc.output) == list(rt.output), (rc.rid,)
    _ok(f"tiered cluster prefix == cold: {stats_t.summary()}")


def suite_spec() -> None:
    from repro.serving.loop import VirtualClock
    from repro.serving.request import synth_workload
    from repro.serving.spec import SpecConfig

    cfg, asg = _setup()

    def wl():
        return synth_workload(rate=10.0, duration=0.5, vocab=cfg.vocab_size,
                              prompt_len=8, prompt_jitter=5, out_len=6,
                              seed=5)

    reqs_b = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_b, deadline=1e9, clock=VirtualClock())
    total = sum(len(r.output) for r in reqs_b)
    # n-gram proposing, then self-draft (the acceptance upper bound) —
    # both must reproduce plain greedy decode token for token, in
    # strictly fewer target decode steps for the draft
    reqs_n = wl()
    st_n = _engine(cfg, asg, cache_layout="paged", block_size=8,
                   spec_decode=True, spec_k=3).serve(
                       reqs_n, deadline=1e9, clock=VirtualClock())
    assert st_n.spec_steps > 0 and st_n.spec_tokens == total
    for rb, rn_ in zip(reqs_b, reqs_n):
        assert list(rb.output) == list(rn_.output), (rb.rid,)
    reqs_d = wl()
    st_d = _engine(cfg, asg, cache_layout="paged", block_size=8,
                   spec_decode=True, spec_k=3,
                   draft_model=cfg).serve(reqs_d, deadline=1e9,
                                          clock=VirtualClock())
    assert st_d.spec_steps < total, (st_d.spec_steps, total)
    for rb, rd in zip(reqs_b, reqs_d):
        assert list(rb.output) == list(rd.output), (rb.rid,)
    _ok(f"spec == greedy (ngram: {st_n.spec_steps} steps, draft: "
        f"{st_d.spec_steps} steps for {total} tokens)")


def suite_quant() -> None:
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    from repro.kernels.paged_attention import (
        paged_context_attention_pallas, paged_decode_attention_pallas,
        paged_verify_attention_pallas)
    from repro.models import quant as Q

    key = jax.random.PRNGKey(0)
    b, hq, hkv, d, bs, nblk = 2, 4, 2, 32, 16, 12
    rn = lambda i, *s: jax.random.normal(jax.random.fold_in(key, i), s)  # noqa: E731
    q, kp, vp = (rn(1, b, 1, hq, d), rn(2, nblk, bs, hkv, d),
                 rn(3, nblk, bs, hkv, d))
    bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6]], np.int32))
    kv_len = jnp.array([41, 64])
    qc = rn(4, b, 8, hq, d)
    q_start = jnp.array([17, 40])
    ctx_len = jnp.array([17 + 8, 40 + 5])
    qv = rn(7, b, 4, hq, d)
    v_start = jnp.array([21, 33])
    v_len = jnp.array([21 + 4, 33 + 2])
    for kv_dtype in ("int8", "fp8"):
        kq, ks = Q.quantize_kv_rows(kp, kv_dtype)
        vq, vs = Q.quantize_kv_rows(vp, kv_dtype)
        kd, vd = Q.dequantize_kv(kq, ks), Q.dequantize_kv(vq, vs)
        with ops.backend("pallas_interpret"):
            out = ops.paged_decode_attention(q, kq, vq, bt, kv_len=kv_len,
                                             k_scale=ks, v_scale=vs)
            out_c = ops.paged_context_attention(
                qc, kq, vq, bt, q_start=q_start, kv_len=ctx_len,
                k_scale=ks, v_scale=vs)
            out_v = ops.paged_verify_attention(
                qv, kq, vq, bt, kv_start=v_start, kv_len=v_len,
                k_scale=ks, v_scale=vs)
        # fused dequant must not change a single bit vs the unquantized
        # kernels on materialized-dequant pages...
        assert np.array_equal(np.asarray(out), np.asarray(
            paged_decode_attention_pallas(q, kd, vd, bt, kv_len=kv_len,
                                          interpret=True))), kv_dtype
        assert np.array_equal(np.asarray(out_c), np.asarray(
            paged_context_attention_pallas(qc, kd, vd, bt, q_start=q_start,
                                           kv_len=ctx_len, interpret=True)))
        assert np.array_equal(np.asarray(out_v), np.asarray(
            paged_verify_attention_pallas(qv, kd, vd, bt, kv_start=v_start,
                                          kv_len=v_len, interpret=True)))
        # ...and sits at the kernel tolerance against the pure-JAX oracles
        np.testing.assert_allclose(np.asarray(out), np.asarray(
            ref.paged_decode_attention_quant_ref(q, kq, vq, ks, vs, bt,
                                                 kv_len=kv_len)), atol=2e-5)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(
            ref.paged_context_attention_quant_ref(
                qc, kq, vq, ks, vs, bt, q_start=q_start, kv_len=ctx_len)),
            atol=2e-5)
        np.testing.assert_allclose(np.asarray(out_v), np.asarray(
            ref.paged_verify_attention_quant_ref(
                qv, kq, vq, ks, vs, bt, kv_start=v_start, kv_len=v_len)),
            atol=2e-5)
    _ok("quantized paged kernels: fused dequant bitwise == materialized, "
        "oracles within 2e-5 (int8 + fp8, interpret mode)")

    # int8 page pools end to end on the multi-device pipeline
    from repro.serving.request import synth_workload

    cfg, asg = _setup()

    def wl():
        return synth_workload(rate=40.0, duration=0.25,
                              vocab=cfg.vocab_size, prompt_len=8,
                              prompt_jitter=5, out_len=4, seed=1)

    reqs_f = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_f, deadline=120.0)
    reqs_q = wl()
    stats_q = _engine(cfg, asg, cache_layout="paged", block_size=8,
                      kv_dtype="int8").serve(reqs_q, deadline=120.0)
    assert stats_q.attainment == 1.0, stats_q.summary()
    assert stats_q.kv_bytes_resident > 0 and stats_q.kv_bytes_saved > 0, \
        stats_q.summary()
    match = sum(list(rf.output) == list(rq.output)
                for rf, rq in zip(reqs_f, reqs_q))
    # KV quantization may legitimately flip a near-tie argmax; on this
    # short workload the vast majority of generations must stay identical
    assert match >= 0.75 * len(reqs_f), (match, len(reqs_f))
    _ok(f"int8 KV serving: {match}/{len(reqs_f)} greedy outputs == fp32, "
        f"{stats_q.summary()}")


def suite_obs() -> None:
    import tempfile
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.report import main as report_main
    from repro.obs.trace import Tracer, validate_chrome_trace
    from repro.serving.loop import VirtualClock
    from repro.serving.request import shared_prefix_workload

    cfg, asg = _setup()

    def wl():
        return shared_prefix_workload(rate=6.0, duration=1.5,
                                      vocab=cfg.vocab_size, shared_len=24,
                                      unique_len=6, out_len=4, seed=9)

    def eng():
        return _engine(cfg, asg, cache_layout="paged", block_size=8,
                       prefix_caching=True, prefill_chunk=16)

    # tracing is pure observation: the traced serve must reproduce the
    # untraced one token for token
    reqs_off = wl()
    eng().serve(reqs_off, deadline=1e9, clock=VirtualClock())
    reqs_on = wl()
    tracer, metrics = Tracer(), MetricsRegistry()
    stats = eng().serve(reqs_on, deadline=1e9, clock=VirtualClock(),
                        tracer=tracer, metrics=metrics)
    for ro, rt in zip(reqs_off, reqs_on):
        assert list(ro.output) == list(rt.output), (ro.rid,)
    errs = validate_chrome_trace(
        tracer.to_chrome(),
        require_spans=["serve", "queue_wait", "iteration", "prefill",
                       "decode"])
    assert not errs, errs
    assert metrics.total("serve_n_requests") == len(reqs_on), \
        metrics.collect()
    # exported artifacts must survive the report CLI's schema gate
    with tempfile.TemporaryDirectory() as td:
        trace_p = os.path.join(td, "trace.json")
        metrics_p = os.path.join(td, "metrics.jsonl")
        tracer.write(trace_p)
        metrics.to_jsonl(metrics_p)
        rc = report_main([metrics_p, "--trace", trace_p,
                          "--require-spans", "prefill,decode"])
        assert rc == 0, rc
    _ok(f"traced == untraced, {len(tracer.events)} events validate "
        f"({stats.summary()})")


def suite_chaos() -> None:
    from repro.configs import get_config
    from repro.core.plan import Assignment, PipelinePlan, StagePlan
    from repro.core.resched import DriftDetector
    from repro.serving.loop import VirtualClock
    from repro.serving.request import synth_workload
    from repro.serving.resched import OnlineRescheduler

    cfg = get_config("granite-8b").reduced()
    L = cfg.num_layers
    # two replicas with different stage splits (the disagg topology):
    # chaos must survive layer regrouping between source and survivors
    asg = Assignment([
        PipelinePlan([StagePlan([0], 1), StagePlan([1], L - 1)],
                     cost=0.1, bottleneck=0.1),
        PipelinePlan([StagePlan([2], L - 1), StagePlan([3], 1)],
                     cost=0.1, bottleneck=0.1),
    ])

    def wl(out_len=4):
        return synth_workload(rate=10.0, duration=1.0, vocab=cfg.vocab_size,
                              prompt_len=10, prompt_jitter=5,
                              out_len=out_len, seed=2)

    # replica kill mid-request: the controller evacuates the dead
    # replica's in-flight work and re-dispatches it from the prompts —
    # survivors regenerate the IDENTICAL token streams (greedy decode),
    # and under --kvsan the kill must release every page (zero leaks)
    reqs_c = wl()
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_c, deadline=1e9, clock=VirtualClock())
    reqs_k = wl()
    eng = _engine(cfg, asg, cache_layout="paged", block_size=8)
    ctl = OnlineRescheduler(kills=[(2.0, 1)])
    eng.router.attach_controller(ctl)
    stats = eng.serve(reqs_k, deadline=1e9, clock=VirtualClock())
    assert stats.dropped == 0, stats.summary()
    kills = [e for e in ctl.events if e["kind"] == "kill"]
    assert kills and kills[0]["orphans"] > 0, ctl.events
    assert ctl.redispatches > 0
    for rc, rk in zip(reqs_c, reqs_k):
        assert list(rc.output) == list(rk.output), (rc.rid,)
    _ok(f"replica kill: {kills[0]['orphans']} orphans re-dispatched, "
        f"tokens == cold ({stats.summary()})")

    # live role re-split mid-decode: decoding slots migrate WITH their
    # emitted tokens (pages + sampling state) and the streams continue
    # exactly where they stopped
    reqs_c2 = wl(out_len=6)
    _engine(cfg, asg, cache_layout="paged",
            block_size=8).serve(reqs_c2, deadline=1e9, clock=VirtualClock())
    reqs_m = wl(out_len=6)
    eng2 = _engine(cfg, asg, cache_layout="paged", block_size=8)
    fired = []

    def resolver(sig, c, now):
        if fired:
            return None
        fired.append(sig.kind)
        return {"roles": ["prefill", "decode"]}

    ctl2 = OnlineRescheduler(
        detector=DriftDetector(rate=1.0, min_events=4, window=5.0),
        resolver=resolver)
    eng2.router.attach_controller(ctl2)
    stats2 = eng2.serve(reqs_m, deadline=1e9, clock=VirtualClock())
    assert stats2.dropped == 0, stats2.summary()
    roles_ev = [e for e in ctl2.events if e["kind"] == "roles"]
    assert roles_ev and roles_ev[0]["moved"] > 0, ctl2.events
    for rc, rm in zip(reqs_c2, reqs_m):
        assert list(rc.output) == list(rm.output), (rc.rid,)
    _ok(f"live role migration: {roles_ev[0]['moved']} slots moved "
        f"mid-decode on {fired[0]}, tokens == cold ({stats2.summary()})")


SUITES = {
    "kernels": suite_kernels,
    "serving": suite_serving,
    "prefix": suite_prefix,
    "disagg": suite_disagg,
    "cluster": suite_cluster,
    "spec": suite_spec,
    "quant": suite_quant,
    "obs": suite_obs,
    "chaos": suite_chaos,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suites", nargs="*", default=[],
                    choices=[*SUITES, []],
                    help="suites to run (default: all)")
    ap.add_argument("--kvsan", action="store_true",
                    help="serve every suite under the KVSAN page-lifecycle "
                         "sanitizer (repro.analysis.kvsan): violations "
                         "raise, leaks fail the suite, tokens must be "
                         "identical to the sanitizer-off baselines the "
                         "suites already compare against")
    args = ap.parse_args()
    global KVSAN
    KVSAN = args.kvsan
    names = args.suites or list(SUITES)
    for name in names:
        SUITES[name]()
    tag = " [kvsan]" if KVSAN else ""
    print(f"smoke_serving: {', '.join(names)} all OK{tag} "
          f"({time.monotonic() - T0:.1f}s)")


if __name__ == "__main__":
    sys.exit(main())
