"""Benchmark orchestrator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only case_study,kernels] [--full]
  PYTHONPATH=src python -m benchmarks.run --check    # validate results/*.jsonl
"""
import argparse
import sys
import time
import traceback

from benchmarks import (bench_calibration, bench_case_study, bench_chaos,
                        bench_continuous, bench_convergence,
                        bench_cost_model,
                        bench_disagg, bench_dryrun_table, bench_kernels,
                        bench_layout_breakdown, bench_offline_resilience,
                        bench_paged, bench_prefix, bench_prefix_cluster,
                        bench_quant_economics, bench_quant_kv,
                        bench_slo_attainment, bench_spec,
                        bench_swarm_compare)
from benchmarks.common import validate_results, write_trajectory

SUITES = {
    "case_study": bench_case_study.run,             # Fig. 1
    "cost_model": bench_cost_model.run,             # Table 3
    "slo_attainment": bench_slo_attainment.run,     # Fig. 2
    "swarm_compare": bench_swarm_compare.run,       # Fig. 3
    "offline_resilience": bench_offline_resilience.run,   # Fig. 4
    "chaos": bench_chaos.run,                       # beyond-paper (online)
    "convergence": bench_convergence.run,           # Fig. 6/7
    "layout_breakdown": bench_layout_breakdown.run,  # Table 4
    "kernels": bench_kernels.run,                   # substrate
    "continuous": bench_continuous.run,             # beyond-paper (Appx D)
    "paged": bench_paged.run,                       # beyond-paper (paged KV)
    "disagg": bench_disagg.run,                     # beyond-paper (HexGen-2)
    "prefix": bench_prefix.run,                     # beyond-paper (prefix KV)
    "prefix_cluster": bench_prefix_cluster.run,     # beyond-paper (tiered KV)
    "spec": bench_spec.run,                         # beyond-paper (spec decode)
    "quant_economics": bench_quant_economics.run,   # beyond-paper (int8)
    "quant_kv": bench_quant_kv.run,                 # beyond-paper (int8 KV)
    "dryrun_table": bench_dryrun_table.run,         # deliverable (g)
    "calibration": bench_calibration.run,           # beyond-paper (HexTrace)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--full", action="store_true",
                    help="run slow variants (both output lengths etc.)")
    ap.add_argument("--check", action="store_true",
                    help="validate every benchmarks/results/*.jsonl row "
                         "against the shared schema (keys, finite "
                         "numbers) and exit; runs no benchmarks")
    args = ap.parse_args()
    if args.check:
        errors = validate_results()
        for e in errors:
            print(f"results check: {e}", file=sys.stderr)
        # the kernel/oracle registry is part of the results contract: a
        # benchmark row for an unregistered (hence unverified) kernel is
        # as untrustworthy as a malformed one
        from repro.analysis.registry import KERNEL_ORACLES, check_registry
        problems = check_registry()
        for p in problems:
            print(f"oracle registry: {p}", file=sys.stderr)
        if errors or problems:
            sys.exit(1)
        print("results check: all rows conform")
        print(f"oracle registry: {len(KERNEL_ORACLES)} kernels all have "
              "oracles + interpret-mode CI checks")
        print(f"trajectory: {write_trajectory()}")
        return
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===")
        try:
            if name == "slo_attainment":
                SUITES[name](fast=not args.full)
            else:
                SUITES[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s")
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
