"""Predicted-vs-observed cost calibration over a traced serve (the PR-10
observability layer: repro.obs trace -> metrics -> calibration ->
DriftDetector model-error signal).

Setup: two colocated paged replicas serve one mixed workload under
``VirtualClock``. The "planner" registers per-(replica, phase) predicted
costs — exactly what ``launch.serve --calibrate`` derives from
``cost_model.pipeline_phase_costs`` — as the virtual per-iteration /
per-token costs both replicas were PLANNED at. Replica 0 runs at plan;
replica 1 is configured ~30% slower than its plan (the degraded-GPU /
stale-profile case the calibration loop exists to catch).

The traced spans then close the loop:

  * the calibration report shows ~0% relative error on replica 0 and the
    injected ~30% on replica 1, per phase (prefill is per-TOKEN from the
    chunked spans' token counts, decode per-SPAN) — asserting the error
    math end to end rather than just that numbers came out;
  * feeding the report into a ``DriftDetector`` fires the ``model_error``
    drift signal naming a drifted phase — the hook ``core.resched`` uses
    to trigger an online re-solve when the cost model stops matching
    reality.

Rows land in results/calibration.jsonl: one per (replica, phase) with
predicted/observed/rel_err, plus a drift summary row whose
``calibration_gap_x`` (observed/planned on the slow replica) is the
trajectory headline.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.core.resched import DriftDetector
from repro.models import model as M
from repro.obs.calibration import CostCalibrator
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request

MAX_LEN = 64
BLOCK = 8
CHUNK = 8
# the planner's per-replica figures: seconds per decode iteration and per
# prefill token (virtual units)
PLAN_STEP = 1.0
PLAN_TOKEN = 0.01
SLOWDOWN = 1.3               # replica 1's reality vs its plan


def _workload(cfg, n=8):
    rng = np.random.RandomState(7)
    reqs = []
    for i in range(n):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=int(rng.randint(12, 28))).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.randint(8, 14)),
                            arrival=0.3 * i))
    return reqs


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    def replica(rid, slowdown):
        return PagedPipelineBatcher(
            pipe(), n_slots=4, max_len=MAX_LEN, block_size=BLOCK,
            prefill_chunk=CHUNK, replica_id=rid,
            virtual_step_cost=PLAN_STEP * slowdown,
            prefill_token_cost=PLAN_TOKEN)

    workers = [replica(0, 1.0), replica(1, SLOWDOWN)]
    reqs = _workload(cfg)
    tracer = Tracer()
    for w in workers:          # Router.bind_tracer does this when serving
        w.tracer = tracer      # through the engine; raw loops wire by hand
    stats = run_serve_loop(workers, reqs, deadline=1e9,
                           clock=VirtualClock(), tracer=tracer)
    errs = validate_chrome_trace(tracer.to_chrome(),
                                 require_spans=["prefill", "decode"])
    assert not errs, errs

    cal = CostCalibrator()
    for rid in (0, 1):
        cal.predict(rid, "decode", PLAN_STEP)
        # engines charge virtual_step_cost * prefill_token_cost per token
        cal.predict(rid, "prefill", PLAN_STEP * PLAN_TOKEN)
    cal.observe_trace(tracer)
    rows = cal.report()
    assert rows, "no calibrated phases observed"
    by = {(r["replica"], r["phase"]): r for r in rows}
    for phase in ("prefill", "decode"):
        if (0, phase) in by:
            assert by[(0, phase)]["rel_err"] < 0.01, by[(0, phase)]
        if (1, phase) in by:
            got = by[(1, phase)]["rel_err"]
            assert abs(got - (SLOWDOWN - 1.0)) < 0.05, by[(1, phase)]

    det = DriftDetector(rate=1.0, model_error_threshold=0.1,
                        model_error_min=2)
    fed = cal.feed(det)
    sig = det.poll(0.0)
    assert sig is not None and sig.kind == "model_error", sig
    emit("calibration/drift", 0.0,
         f"fed={fed} rows -> {sig.describe()}")

    for r in rows:
        emit(f"calibration/r{r['replica']}/{r['phase']}", 0.0,
             f"pred={r['predicted']:.4g} obs={r['observed']:.4g} "
             f"rel_err={r['rel_err'] * 100:.1f}% spans={r['spans']}")
        emit_json("calibration.jsonl",
                  f"calibration_r{r['replica']}_{r['phase']}", {
                      "arch": cfg.name, "replica": r["replica"],
                      "phase": r["phase"],
                      "predicted": float(r["predicted"]),
                      "observed": float(r["observed"]),
                      "rel_err": float(r["rel_err"]),
                      "spans": r["spans"], "units": float(r["units"]),
                  })
    emit_json("calibration.jsonl", "calibration_drift", {
        "arch": cfg.name, "n_requests": len(reqs),
        "iterations": stats.iterations,
        "trace_events": len(tracer.events),
        "planned_step": PLAN_STEP,
        "calibration_gap_x": float(SLOWDOWN),
        "drift_fired": True, "drift_phase": sig.phase,
        "drift_factor": float(sig.factor),
        "rows_fed": fed,
    })
    emit("calibration/summary", 0.0, cal.summary())


if __name__ == "__main__":
    run()
