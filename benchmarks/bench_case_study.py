"""Paper Fig. 1 — case study: parallelism over heterogeneity.

LLAMA-2 (70B) on 4xA6000 + 2xA5000 + 2xA4000, input 128 / output 64.
Reproduces: TP=8 OOM, even PP=8 OOM, PP8-proportional and PP2xTP4 slow,
asymmetric [4,2,2] with 48/20/12 layers fastest."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.dp_layout import optimize_pipeline


def run() -> None:
    c = cl.case_study_cluster()
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    task = cm.Task(batch=1, s_in=128, s_out=64)

    oom_tp8 = not cm.mem_ok(c, list(range(8)), 80, prof, task)
    oom_pp8 = not cm.mem_ok(c, [6], 10, prof, task)
    emit("case_study/tp8", 0.0, f"OOM={oom_tp8} (paper: OOM)")
    emit("case_study/pp8_even", 0.0, f"OOM={oom_pp8} (paper: OOM)")

    layouts = {
        "pp8_proportional": ([[d] for d in range(8)],
                             [14, 14, 14, 14, 7, 7, 5, 5]),
        "pp2_tp4_crossmachine": ([[0, 1, 2, 3], [4, 5, 6, 7]], [56, 24]),
        "hexgen_asym_4_2_2": ([[0, 1, 2, 3], [4, 5], [6, 7]], [48, 20, 12]),
    }
    costs = {}
    for name, (stages, split) in layouts.items():
        costs[name] = cm.pipeline_cost(c, stages, split, prof, task)
        emit(f"case_study/{name}", costs[name] * 1e6,
             f"latency={costs[name]:.2f}s")
    hx = costs["hexgen_asym_4_2_2"]
    emit("case_study/speedup_vs_pp8", 0.0,
         f"{costs['pp8_proportional']/hx:.2f}x (paper: ~2x)")
    emit("case_study/speedup_vs_pp2tp4", 0.0,
         f"{costs['pp2_tp4_crossmachine']/hx:.2f}x (paper: up to 19x)")

    plan = optimize_pipeline(c, list(range(8)), prof, task)
    emit("case_study/dp_best", plan.cost * 1e6,
         f"layout={plan.describe()} latency={plan.cost:.2f}s")


if __name__ == "__main__":
    run()
