"""Quantized KV page pools (int8/fp8) vs model-precision pools, swept
over kv_dtype x concurrency on the paged serving engine.

Three economics, one file:

1. CAPACITY — at an EQUAL per-stage byte budget, an int8 pool affords
   ~4x the blocks of fp32 (payload / 4, plus the per-token-per-head f32
   scales), so the same workload runs far more concurrent slots and
   stops preempting. The acceptance bar: >= 2x peak concurrent slots
   for int8 at the same bytes.
2. WIRE — disaggregated prefill/decode ships the quantized payload +
   scales verbatim, so the modeled KV handoff drops ~4x in bytes and
   the p50 TTFT on a slow link drops with it. The acceptance bar:
   >= 2x migration-byte reduction, measured AND modeled
   (cost_model.kv_migration_bytes at kv_dtype="int8").
3. QUALITY — greedy decode over quantized pages may flip a near-tie
   argmax; the token-match rate against fp32 serving quantifies how
   rarely. (Exact-identity claims live in the tier-1 tests; this is
   the statistical complement.)

Rows land in results/quant_kv.jsonl (CI's --check guard validates them).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.core import cost_model as cm
from repro.models import model as M
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.disagg import KVLink, wire_disaggregation
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request, synth_workload

MAX_LEN = 64
BLOCK = 8
BUDGET_BYTES = 128 * 1024        # per-stage pool budget for the capacity sweep
N_SLOTS = 24
LINK_GBPS = 1e-5                 # slow modeled KV link (virtual clock units)

# payload bytes per element in the page pool (cost_model's table, minus
# the per-token-per-head f32 scale accounted separately below)
PAYLOAD_BYTES = {None: 4.0, "bf16": 2.0, "int8": 1.0, "fp8": 1.0}
QUANTIZED = ("int8", "fp8")


def _pool_block_bytes(cfg, kv_dtype) -> int:
    """Bytes one (block_size, hkv, hd) K+V page pair costs at kv_dtype,
    including the f32 scale rows a quantized pool carries."""
    hkv, hd = cfg.num_kv_heads, cfg.head_dim_
    payload = 2 * BLOCK * hkv * hd * PAYLOAD_BYTES[kv_dtype]
    scales = 2 * BLOCK * hkv * 4 if kv_dtype in QUANTIZED else 0
    return int(payload + scales)


def _workload(cfg, *, n=24, seed=7):
    """Mixed lengths: mostly short chats, a few long documents — the
    regime where pool bytes, not slot bookkeeping, bound concurrency."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        long = (i % 8 == 7)
        plen = int(rng.randint(24, 40)) if long else int(rng.randint(4, 10))
        out = 12 if long else 6
        reqs.append(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab_size,
                                      size=plen).astype(np.int32),
            max_new_tokens=out, arrival=0.0))
    return reqs


class _PeakConcurrency:
    """Wraps a slot engine to record the peak number of occupied slots."""

    def __init__(self, eng):
        self.eng = eng
        self.peak = 0

    def __getattr__(self, name):
        return getattr(self.eng, name)

    def run_iteration(self, now):
        out = self.eng.run_iteration(now)
        busy = sum(1 for s in self.eng.slots if not s.free)
        self.peak = max(self.peak, busy)
        return out


def _pipe(cfg, params):
    dev = jax.devices()[0]
    L = cfg.num_layers
    return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # ---- 1. capacity at an equal byte budget -----------------------------
    sweep = {}
    for kv_dtype in (None, "bf16", "int8", "fp8"):
        n_blocks = BUDGET_BYTES // _pool_block_bytes(cfg, kv_dtype) + 1
        eng = _PeakConcurrency(PagedPipelineBatcher(
            _pipe(cfg, params), n_slots=N_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK, stage_blocks=[n_blocks, n_blocks],
            kv_dtype=kv_dtype))
        st = run_serve_loop([eng], _workload(cfg), deadline=1e9,
                            clock=VirtualClock())
        name = kv_dtype or cfg.dtype
        sweep[kv_dtype] = (eng.peak, st)
        emit(f"quant_kv/capacity/{name}", 0.0,
             f"blocks={n_blocks} peak={eng.peak}/{N_SLOTS} "
             f"preempt={st.preemptions} iters={st.iterations} "
             f"thpt={st.throughput:.3f} req/iter "
             f"kv={st.kv_bytes_resident / 1e6:.2f}MB "
             f"saved={st.kv_bytes_saved / 1e6:.2f}MB")
    peak_f, st_f = sweep[None]
    peak_q, st_q = sweep["int8"]
    slots_gain = peak_q / max(peak_f, 1)
    emit("quant_kv/capacity_gain", 0.0,
         f"{slots_gain:.2f}x concurrent slots, preemptions "
         f"{st_f.preemptions} -> {st_q.preemptions} at the same "
         f"{BUDGET_BYTES // 1024}KiB/stage budget")

    # ---- 2. greedy token-match rate vs fp32 (roomy pools) ---------------
    roomy = dict(n_slots=4, max_len=MAX_LEN, block_size=BLOCK)
    wl = synth_workload(rate=20.0, duration=0.6, vocab=cfg.vocab_size,
                        prompt_len=8, prompt_jitter=5, out_len=12, seed=11)
    base = [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, arrival=r.arrival)
            for r in wl]
    run_serve_loop([PagedPipelineBatcher(_pipe(cfg, params), **roomy)],
                   base, deadline=1e9, clock=VirtualClock())
    match_rates = {}
    for kv_dtype in ("bf16", "int8", "fp8"):
        reqs = [Request(rid=r.rid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, arrival=r.arrival)
                for r in wl]
        run_serve_loop([PagedPipelineBatcher(_pipe(cfg, params),
                                             kv_dtype=kv_dtype, **roomy)],
                       reqs, deadline=1e9, clock=VirtualClock())
        agree = total = exact = 0
        for rb, rq in zip(base, reqs):
            a, b = list(rb.output), list(rq.output)
            agree += sum(x == y for x, y in zip(a, b))
            total += len(a)
            exact += a == b
        match_rates[kv_dtype] = agree / max(total, 1)
        emit(f"quant_kv/token_match/{kv_dtype}", 0.0,
             f"{agree}/{total} tokens == fp32 "
             f"({match_rates[kv_dtype]:.3f}), {exact}/{len(base)} "
             "outputs exact")

    # ---- 3. disaggregation wire: migration bytes + p50 TTFT -------------
    def serve_disagg(kv_dtype):
        reqs = synth_workload(rate=0.1, duration=120.0,
                              vocab=cfg.vocab_size, prompt_len=32,
                              prompt_jitter=8, out_len=4, seed=9)
        workers = [PagedPipelineBatcher(
            _pipe(cfg, params), n_slots=4, max_len=MAX_LEN,
            block_size=BLOCK, role=role, replica_id=i, kv_dtype=kv_dtype)
            for i, role in enumerate(["prefill", "decode"])]
        wire_disaggregation(workers, ["prefill", "decode"],
                            KVLink(gbps=LINK_GBPS))
        st = run_serve_loop(workers, reqs, deadline=1e9,
                            clock=VirtualClock())
        ttft = np.asarray([r.first_token_time - r.arrival for r in reqs])
        # TTFT lands at prefill completion, BEFORE the page handoff; the
        # end-to-end latency carries the modeled transfer stall
        lat = np.asarray(st.latencies)
        return (st, float(np.percentile(ttft, 50)),
                float(np.percentile(lat, 50)), reqs)

    st_df, p50_f, lat_f, reqs_f = serve_disagg(None)
    st_dq, p50_q, lat_q, reqs_q = serve_disagg("int8")
    wire_gain = st_df.migrated_kv_bytes / max(st_dq.migrated_kv_bytes, 1)
    # the modeled counterpart the scheduler prices (fp32 task vs int8 KV)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True, bytes_per_el=4)
    task4 = cm.Task(batch=1, s_in=128, s_out=64, bytes_per_el=4)
    modeled_gain = (cm.kv_migration_bytes(prof, task4, block_size=16)
                    / cm.kv_migration_bytes(prof, task4, block_size=16,
                                            kv_dtype="int8"))
    emit("quant_kv/disagg_wire", 0.0,
         f"migrated {st_df.migrated_kv_bytes / 1e6:.2f}MB -> "
         f"{st_dq.migrated_kv_bytes / 1e6:.2f}MB ({wire_gain:.2f}x), "
         f"p50 TTFT {p50_f:.2f} -> {p50_q:.2f}, p50 latency "
         f"{lat_f:.2f} -> {lat_q:.2f} on a {LINK_GBPS}GB/s link; "
         f"modeled {modeled_gain:.2f}x")

    emit_json("quant_kv.jsonl", "quant_kv", {
        "arch": cfg.name, "budget_bytes": BUDGET_BYTES,
        "block_size": BLOCK, "max_len": MAX_LEN, "n_slots": N_SLOTS,
        **{f"capacity_peak_{kv or 'fp32'}": sweep[kv][0]
           for kv in sweep},
        **{f"capacity_preempt_{kv or 'fp32'}": sweep[kv][1].preemptions
           for kv in sweep},
        **{f"capacity_blocks_{kv or 'fp32'}":
           BUDGET_BYTES // _pool_block_bytes(cfg, kv) + 1 for kv in sweep},
        "slots_gain_x": float(slots_gain),
        **{f"token_match_{kv}": float(match_rates[kv])
           for kv in match_rates},
        "disagg_link_gbps": LINK_GBPS,
        "disagg_migrated_mb_fp32": st_df.migrated_kv_bytes / 1e6,
        "disagg_migrated_mb_int8": st_dq.migrated_kv_bytes / 1e6,
        "disagg_p50_ttft_fp32": p50_f,
        "disagg_p50_ttft_int8": p50_q,
        "disagg_p50_latency_fp32": lat_f,
        "disagg_p50_latency_int8": lat_q,
        "wire_gain_x": float(wire_gain),
        "modeled_migration_gain_x": float(modeled_gain),
    })

    assert slots_gain >= 2.0, \
        f"acceptance: int8 pools should serve >=2x slots, got {slots_gain:.2f}x"
    assert wire_gain >= 2.0 and modeled_gain >= 2.0, \
        f"acceptance: >=2x migration-byte cut, got {wire_gain:.2f}x " \
        f"measured / {modeled_gain:.2f}x modeled"
    assert lat_q <= lat_f, (lat_q, lat_f)
    assert match_rates["int8"] >= 0.85, match_rates


if __name__ == "__main__":
    run()
