"""Paper Table 3 — cost-model fidelity: estimated prefill/decode times for
LLAMA-2 (70B) on 8xA100-40G under TP8 / TP4+PP2 / TP2+PP4 / PP8, compared
against the paper's published Benchmarked and Estimated columns.

Our constants (A100 specs + NVLink alpha/beta) differ from the paper's
unpublished calibration, so we report ratios; orderings must match."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm

# paper Table 3: (config, in/out) -> (prefill_bench, prefill_est,
#                                     decode_bench, decode_est)
PAPER = {
    ("TP=8", 256, 32): (2.72, 2.99, 2.43, 2.46),
    ("TP=4 PP=2", 256, 32): (3.79, 3.85, 2.25, 2.14),
    ("TP=2 PP=4", 256, 32): (5.26, 5.25, 3.29, 3.04),
    ("PP=8", 256, 32): (8.04, 7.83, 6.04, 5.60),
    ("TP=8", 512, 64): (3.04, 3.10, 4.76, 4.92),
    ("TP=4 PP=2", 512, 64): (4.16, 4.10, 4.32, 4.28),
    ("TP=2 PP=4", 512, 64): (5.57, 5.63, 6.65, 6.08),
    ("PP=8", 512, 64): (8.27, 8.49, 12.40, 11.20),
}

LAYOUTS = {
    "TP=8": ([list(range(8))], [80]),
    "TP=4 PP=2": ([[0, 1, 2, 3], [4, 5, 6, 7]], [40, 40]),
    "TP=2 PP=4": ([[0, 1], [2, 3], [4, 5], [6, 7]], [20, 20, 20, 20]),
    "PP=8": ([[d] for d in range(8)], [10] * 8),
}


def split_prefill_decode(cluster, stages, split, prof, task, *,
                         pp_lat=None, pp_bw=None):
    """Separate the cost-model terms into prefill vs decode components."""
    pre = dec = 0.0
    B = task.bytes_per_el
    H = prof.d_model
    for j, (devs, l) in enumerate(zip(stages, split)):
        n = len(devs)
        specs = [cluster.devices[d].spec for d in devs]
        # decode: parameter scan + per-token matmul
        dec += max(prof.params_per_layer * B * task.s_out / (n * s.mem_bw)
                   for s in specs) * l
        dec += max(prof.flops_per_layer_per_token * task.batch * task.s_out
                   / (n * s.flops) for s in specs) * l
        # prefill: prompt matmul
        pre += max(prof.flops_per_layer_per_token * task.batch * task.s_in
                   / (n * s.flops) for s in specs) * l
        if n > 1:
            def superstep(msg):
                best = 0.0
                for d in devs:
                    tot = sum(cluster.lat[d, d2] + msg / (n * cluster.bw[d, d2])
                              for d2 in devs if d2 != d)
                    best = max(best, tot)
                return best
            pre += superstep(task.batch * task.s_in * H * B) * 4 * l
            dec += superstep(task.batch * H * B) * 4 * task.s_out * l
        if j + 1 < len(stages):
            nxt = stages[j + 1]
            link = min((cluster.lat[d, d2], d, d2) for d in devs
                       for d2 in nxt)
            a = pp_lat if pp_lat is not None else link[0]
            bw = pp_bw if pp_bw is not None else cluster.bw[link[1], link[2]]
            pre += a + task.batch * task.s_in * H * B / bw
            dec += (a + task.batch * H * B / bw) * task.s_out
    return pre, dec


# Best-fit effective constants against Table 3 (see EXPERIMENTS.md §Repro:
# the paper's prefill column implies ~1 ms per AllReduce while its decode
# column implies ~20 us under the published formulas, so no single (alpha,
# beta) reproduces both; this profile minimizes joint log-error -- decode
# lands within 1.3-1.6x and every ordering matches).
CALIBRATED = dict(alpha=5e-5, beta=2.0e9, pp_alpha=2e-2, pp_beta=5e8)


def _calibrated_cluster():
    import numpy as np
    homo = cl.homogeneous_a100()
    n = len(homo)
    lat = np.full((n, n), CALIBRATED["alpha"])
    bw = np.full((n, n), CALIBRATED["beta"])
    np.fill_diagonal(lat, 0)
    return cl.Cluster(homo.devices, lat, bw)


def run() -> None:
    homo = cl.homogeneous_a100()
    calib = _calibrated_cluster()
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    for (name, s_in, s_out), (pb, pe, db, de) in PAPER.items():
        task = cm.Task(batch=1, s_in=s_in, s_out=s_out)
        stages, split = LAYOUTS[name]
        pre, dec = split_prefill_decode(homo, stages, split, prof, task)
        pre_c, dec_c = split_prefill_decode(
            calib, stages, split, prof, task,
            pp_lat=CALIBRATED["pp_alpha"], pp_bw=CALIBRATED["pp_beta"])
        emit(f"cost_model/{name.replace(' ', '_')}/{s_in}_{s_out}", 0.0,
             f"prefill={pre:.2f}s calib={pre_c:.2f}s (paper bench {pb} est {pe}) "
             f"decode={dec:.2f}s calib={dec_c:.2f}s (paper bench {db} est {de})")
    # ordering check: decode PP=8 > TP=2PP=4 > TP=8 scan-bound ordering
    task = cm.Task(batch=1, s_in=256, s_out=32)
    decs = {}
    for name, (stages, split) in LAYOUTS.items():
        _, decs[name] = split_prefill_decode(homo, stages, split, prof, task)
    ok = decs["PP=8"] > decs["TP=2 PP=4"] > decs["TP=4 PP=2"]
    emit("cost_model/ordering", 0.0,
         f"PP8>TP2PP4>TP4PP2={ok} (paper: same ordering)")


if __name__ == "__main__":
    run()
