"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import glob
import json
import math
import os
import time
from typing import Callable, List

ROWS: List[str] = []

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit_json(filename: str, name: str, payload: dict) -> None:
    """Append one schema-conforming row to results/<filename> — the
    perf-trajectory files CI's --check guard validates."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = {"bench": name, **payload}
    errs = _validate_row(row)
    assert not errs, errs
    with open(os.path.join(RESULTS_DIR, filename), "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    print("# json: " + json.dumps(row, sort_keys=True))


def _validate_value(key: str, v) -> List[str]:
    # bool before int: bool IS an int, and True is a fine flag value
    if isinstance(v, bool) or isinstance(v, str):
        return []
    if isinstance(v, (int, float)):
        return [] if math.isfinite(v) else \
            [f"{key}: non-finite number {v!r}"]
    if isinstance(v, list):
        out: List[str] = []
        for i, e in enumerate(v):
            out += _validate_value(f"{key}[{i}]", e)
        return out
    return [f"{key}: unsupported value type {type(v).__name__}"]


def _validate_row(row) -> List[str]:
    """One results row: a flat-ish JSON object with a non-empty "bench"
    name and every value a string/bool/finite number (or a list of
    those). NaN/Infinity — the classic way a perf file silently rots —
    is a hard error."""
    if not isinstance(row, dict):
        return [f"row is {type(row).__name__}, not an object"]
    errs: List[str] = []
    if not isinstance(row.get("bench"), str) or not row.get("bench"):
        errs.append('missing/empty "bench" name')
    for k, v in row.items():
        if not isinstance(k, str) or not k:
            errs.append(f"non-string key {k!r}")
            continue
        errs += _validate_value(k, v)
    return errs


def validate_results(results_dir: str = RESULTS_DIR) -> List[str]:
    """Validate every results/*.jsonl row; returns human-readable errors
    (empty = clean). Used by ``python -m benchmarks.run --check`` in CI."""
    errors: List[str] = []
    paths = sorted(glob.glob(os.path.join(results_dir, "*.jsonl")))
    if not paths:
        return [f"no *.jsonl files under {results_dir}"]
    for path in paths:
        rel = os.path.basename(path)
        rows = 0
        with open(path) as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                rows += 1
                try:
                    row = json.loads(
                        line,
                        parse_constant=lambda c: float("nan"))
                except ValueError as e:
                    errors.append(f"{rel}:{ln}: unparseable JSON ({e})")
                    continue
                errors += [f"{rel}:{ln}: {e}" for e in _validate_row(row)]
        if rows == 0:
            # an empty file is a rotten perf trajectory, not a clean one —
            # "zero rows, zero errors" must not pass vacuously
            errors.append(f"{rel}: no result rows (empty file)")
    return errors


# headline-metric selection for write_trajectory: first substring (in
# order) found among a row's numeric keys wins — ratios and reductions
# are the metrics worth tracking release-over-release, raw timings last
_HEADLINE_HINTS = ("speedup", "_x", "reduction", "p50", "attainment",
                   "hit_rate", "us_per_call")


def _headline_metric(row: dict):
    numeric = {k: v for k, v in row.items()
               if isinstance(v, (int, float)) and not isinstance(v, bool)}
    for hint in _HEADLINE_HINTS:
        for k in sorted(numeric):
            if hint in k:
                return k, numeric[k]
    for k in sorted(numeric):
        return k, numeric[k]
    return None


def _metric_units(key: str) -> str:
    if key.endswith("_x") or "speedup" in key:
        return "x"
    if "us" in key.split("_"):
        return "us"
    if "bytes" in key:
        return "bytes"
    if "ttft" in key or "latency" in key:
        return "virtual iters"
    if "tokens" in key or key.endswith("_tok"):
        return "tokens"
    if ("rate" in key or "attainment" in key or "frac" in key
            or "reduction" in key):
        return "fraction"
    return ""


def write_trajectory(results_dir: str = RESULTS_DIR,
                     out_path: str = None) -> str:
    """Consolidate the NEWEST row of every bench across results/*.jsonl
    into one trajectory file: [{bench, metric, value, units, date,
    source}]. One glanceable row per benchmark — the release-over-
    release perf record ``run.py --check`` refreshes after validation."""
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), os.pardir,
                                "BENCH_trajectory.json")
    latest = {}   # bench name -> (mtime, source file, row)
    for path in sorted(glob.glob(os.path.join(results_dir, "*.jsonl"))):
        mtime = os.path.getmtime(path)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except ValueError:
                    continue
                name = row.get("bench")
                if not name:
                    continue
                prev = latest.get(name)
                # later rows in the same file are newer re-runs
                if prev is None or mtime >= prev[0]:
                    latest[name] = (mtime, os.path.basename(path), row)
    out = []
    for name in sorted(latest):
        mtime, source, row = latest[name]
        head = _headline_metric(row)
        if head is None:
            continue
        key, value = head
        out.append({
            "bench": name, "metric": key, "value": value,
            "units": _metric_units(key),
            "date": time.strftime("%Y-%m-%d", time.localtime(mtime)),
            "source": source,
        })
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return os.path.abspath(out_path)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def time_us(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(iters):
        fn()
    return (time.monotonic() - t0) / iters * 1e6
