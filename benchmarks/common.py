"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time
from typing import Callable, List

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def time_us(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(iters):
        fn()
    return (time.monotonic() - t0) / iters * 1e6
