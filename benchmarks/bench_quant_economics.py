"""Beyond-paper: int8 weight-only quantization through the HexGen economics
lens — B_type=1 halves the cost model's parameter memory AND the
memory-scan decode term, so the scheduler packs more (and faster) replicas
into the same budget. (The paper cites quantization as related work; here
it composes with its scheduler.)"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.scheduler import schedule


def run() -> None:
    for setting, pool in (("half_price", cl.hetero_half_price()),
                          ("case_study", cl.case_study_cluster())):
        for name, bte in (("bf16", 2), ("int8", 1)):
            task = cm.Task(batch=1, s_in=128, s_out=32, bytes_per_el=bte)
            res = schedule(pool, "llama2-70b", task, deadline=10.0,
                           rate=6.0, iters=15, seed=0, paper_exact=True)
            reps = [slo_sim.ReplicaModel(p.cost, p.bottleneck)
                    for p in res.assignment.pipelines]
            peak = slo_sim.peak_rate_for_attainment(reps, 5.0, target=0.9,
                                                    duration=60.0)
            emit(f"quant/{setting}/{name}", 0.0,
                 f"replicas={res.assignment.num_replicas} "
                 f"peak_rate@5s={peak:.2f}req/s "
                 f"layout={res.assignment.describe()[:70]}")


if __name__ == "__main__":
    run()
