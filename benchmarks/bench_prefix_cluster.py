"""Cluster-scale prefix reuse: shared directory + host page tier +
prefix-aware routing vs private per-replica caches.

Two replicas serve a workload of hot prompt FAMILIES (long shared head,
short unique tail) whose combined working set does NOT fit any single
replica's device pool. The private-cache baseline loses twice: least-
loaded routing scatters a family's revisits across replicas (each cache
holds a cold copy), and pool pressure EVICTS the shared heads outright,
so revisits re-prefill. The cluster treatment demotes evicted heads to a
host tier, swaps them back on re-hit, fetches peer-resident heads over
the modeled link, and routes revisits to the replica already holding the
family — so prefill collapses to first-toucher + tails.

Both sides pay the same ``prefill_token_cost`` on the virtual clock, and
host swaps/fetches are charged there too, so the TTFT delta is earned
reuse, not free transfers. Token streams must stay bit-identical to cold
contiguous serving (tiers change where pages COME FROM, never what gets
generated).

Rows land in results/prefix_cluster.jsonl (run.py --check validates and
folds them into BENCH_trajectory.json).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import PipelineBatcher
from repro.serving.loop import VirtualClock
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request
from repro.serving.router import Router

N_FAMILIES = 3
N_VISITS = 8
SHARED_LEN = 40              # 5 whole blocks of 8: the hot head
TAIL_LEN = 8
OUT_LEN = 4
BLOCK = 8
MAX_LEN = 56  # 40 + 8 + 4 rounded to whole blocks
STAGE_BLOCKS = [12, 12]      # 11 usable/stage: < the 15-block shared set
HOST_BLOCKS = 64
TOKEN_COST = 0.5            # virtual iteration fraction per prefill token
SWAP_COST = 0.02             # virtual iteration fraction per swapped block
ARRIVAL_GAP = 3.0            # sparse enough that TTFT is prefill, not queue


def _workload(cfg):
    """N_FAMILIES hot families, N_VISITS visits each, interleaved so
    every family's head is long cold between revisits under LRU."""
    reqs = []
    rid = 0
    for visit in range(N_VISITS):
        for fam in range(N_FAMILIES):
            rng = np.random.RandomState(100 + fam)
            head = rng.randint(0, cfg.vocab_size, SHARED_LEN)
            tail = np.random.RandomState(1000 + rid).randint(
                0, cfg.vocab_size, TAIL_LEN)
            reqs.append(Request(
                rid=rid,
                prompt=np.concatenate([head, tail]).astype(np.int32),
                max_new_tokens=OUT_LEN, arrival=ARRIVAL_GAP * rid))
            rid += 1
    return reqs


def _serve(mk_replicas, reqs, **kw):
    router = Router(mk_replicas(), n_slots=2, max_len=MAX_LEN,
                    cache_layout="paged", block_size=BLOCK,
                    stage_blocks=STAGE_BLOCKS, prefix_caching=True,
                    prefill_token_cost=TOKEN_COST, **kw)
    stats = router.serve(reqs, deadline=1e9, clock=VirtualClock())
    ttft = [r.first_token_time - r.arrival for r in reqs
            if r.first_token_time is not None]
    return stats, float(np.percentile(ttft, 50))


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def mk_replicas():
        return [AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])
                for _ in range(2)]

    # cold contiguous reference: the token-identity oracle
    reqs_cold = _workload(cfg)
    PipelineBatcher(
        AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]]),
        n_slots=2, max_len=MAX_LEN).serve(reqs_cold, deadline=1e9)

    # baseline: private per-replica caches, least-loaded routing,
    # eviction deletes
    reqs_b = _workload(cfg)
    st_b, p50_b = _serve(mk_replicas, reqs_b)

    # treatment: shared directory + host tier + prefix-aware routing
    reqs_t = _workload(cfg)
    st_t, p50_t = _serve(mk_replicas, reqs_t, host_blocks=HOST_BLOCKS,
                         host_swap_cost=SWAP_COST, cluster_prefix=True,
                         prefix_route_weight=0.5)

    # (c) tiers and the directory are invisible to the token stream
    for rc, rb, rt in zip(reqs_cold, reqs_b, reqs_t):
        assert list(rc.output) == list(rb.output), rb.rid
        assert list(rc.output) == list(rt.output), rt.rid

    total_prompt = sum(len(r.prompt) for r in reqs_b)
    # cache-served fraction of prompt tokens: whatever was NOT prefilled
    # came from a tier (device hit, host promotion, or peer fetch)
    hit_b = 1.0 - st_b.prefill_tokens / total_prompt
    hit_t = 1.0 - st_t.prefill_tokens / total_prompt
    # (a) the cluster serves strictly more prompt tokens from cache
    assert hit_t > hit_b, (hit_t, hit_b)
    # (b) routed + tiered reuse buys >= 2x p50 TTFT
    speedup = p50_b / max(p50_t, 1e-9)
    assert speedup >= 2.0, (p50_b, p50_t)

    emit("prefix_cluster/private_baseline", 0.0,
         f"prefill={st_b.prefill_tokens}tok hit={hit_b * 100:.0f}% "
         f"p50_ttft={p50_b:.2f} preempt={st_b.preemptions}")
    emit("prefix_cluster/cluster_tiered", 0.0,
         f"prefill={st_t.prefill_tokens}tok hit={hit_t * 100:.0f}% "
         f"p50_ttft={p50_t:.2f} host={st_t.host_promotions}in/"
         f"{st_t.host_demotions}out fetch={st_t.prefix_fetches}")
    emit("prefix_cluster/gain", 0.0,
         f"{speedup:.2f}x p50 TTFT, cache-served "
         f"{hit_b * 100:.0f}% -> {hit_t * 100:.0f}% on a "
         f"{N_FAMILIES}-family working set {sum(STAGE_BLOCKS[:1]) * 2}"
         f"-block pools cannot hold")
    emit_json("prefix_cluster.jsonl", "prefix_cluster_vs_private", {
        "arch": cfg.name, "n_requests": len(reqs_b),
        "n_families": N_FAMILIES, "shared_len": SHARED_LEN,
        "block_size": BLOCK, "stage_blocks": STAGE_BLOCKS,
        "host_blocks": HOST_BLOCKS, "host_swap_cost": SWAP_COST,
        "prefill_token_cost": TOKEN_COST,
        "base_prefill_tokens": st_b.prefill_tokens,
        "cluster_prefill_tokens": st_t.prefill_tokens,
        "base_hit_rate": float(hit_b),
        "cluster_hit_rate": float(hit_t),
        "host_demotions": st_t.host_demotions,
        "host_promotions": st_t.host_promotions,
        "host_hit_tokens": st_t.host_hit_tokens,
        "prefix_fetches": st_t.prefix_fetches,
        "prefix_fetched_bytes": st_t.prefix_fetched_bytes,
        "base_p50_ttft": p50_b, "cluster_p50_ttft": p50_t,
        "p50_ttft_speedup_x": float(speedup),
        "token_identical": True,
    })


if __name__ == "__main__":
    run()
