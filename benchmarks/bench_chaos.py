"""Chaos: online rescheduling vs the static incumbent under live faults.

Extends bench_offline_resilience (Fig. 4's offline re-search) into the
ONLINE regime of core.resched + serving.resched: the same serve loop, but
the world misbehaves mid-run —

  * ``chaos/kill``  — a replica dies mid-request. Static serving loses
    its in-flight requests (attainment hit); the online controller
    evacuates them, re-dispatches onto survivors (cold re-prefill, never
    a wrong token) and warm re-solves the diminished pool.
  * ``chaos/spike`` — arrivals spike 10x for a window. The drift
    detector fires on the rate window; the resolver warm re-solves at
    the OBSERVED rate and live-replaces the layout when the re-solve
    simulates strictly better than the incumbent under that rate.
  * ``chaos/mix``   — the prompt-length mix shifts (4x longer prompts).
    Plan-level comparison: the incumbent (sized for short prompts)
    vs a warm re-solve against the observed mix, both simulated under
    the new task; plus the detector firing on the mix window.

Workers are the closed-form analytic replicas of core.slo_sim driven
through the REAL controller (serving.resched.OnlineRescheduler) on the
real serve loop, so loop dynamics — orphan re-dispatch, membership
edits, dispatcher repair — are the production code paths, only the
per-iteration compute is modeled. Results land in results/chaos.jsonl
for the --check trajectory."""
from __future__ import annotations

import time

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import genetic, slo_sim
from repro.core.resched import DriftDetector, drop_devices, warm_resolve
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.request import Request
from repro.serving.resched import OnlineRescheduler

# Per-scenario operating points. The kill runs under a TIGHT SLO
# (losing in-flight work is what hurts); the spike runs with deadline
# headroom (capacity from the re-solve is what saves the backlog).
KILL_DEADLINE, KILL_RATE, KILL_DURATION = 10.0, 3.0, 40.0
SPIKE_DEADLINE, SPIKE_RATE = 30.0, 1.5


def _models(pool, asg, prof, task):
    """Colocated ReplicaModels for every pipeline of an assignment."""
    out = []
    for pipe in asg.pipelines:
        pc = cm.pipeline_phase_costs(pool, [st.device_ids for st in
                                            pipe.stages],
                                     pipe.layer_split, prof, task)
        out.append(slo_sim.PhasedReplicaModel(
            prefill_latency=pc.prefill_latency,
            prefill_bottleneck=pc.prefill_bottleneck,
            decode_latency=pc.decode_latency,
            decode_bottleneck=pc.decode_bottleneck).colocated())
    return out


def _workers(models, asg, start_id=0):
    ws = []
    for i, (m, pipe) in enumerate(zip(models, asg.pipelines)):
        w = slo_sim.AnalyticWorker(m)
        w.replica_id = start_id + i
        w.device_ids = tuple(pipe.device_ids)   # death key for the detector
        ws.append(w)
    return ws


def _run(workers, arrivals, deadline, ctl=None):
    reqs = [Request(rid=i, prompt=slo_sim._EMPTY_PROMPT, max_new_tokens=0,
                    arrival=float(t)) for i, t in enumerate(arrivals)]
    lst = list(workers)
    dispatch = None
    if ctl is not None:
        lst.append(ctl)
        ctl.bind_workers(lst)
        if ctl.detector is not None:
            # the engine path feeds the detector from Router._dispatch;
            # the bare analytic loop taps its admissions the same way
            def dispatch(cands, req, now):
                ctl.observe_admit(now, req)
                return min(cands, key=lambda c: (
                    c.load(now), getattr(c, "replica_id", 0)))
    stats = run_serve_loop(lst, reqs, deadline=deadline,
                           clock=VirtualClock(), dispatch=dispatch)
    return stats


class _StaticKiller(OnlineRescheduler):
    """The no-rescheduling baseline: the kill still happens, but the dead
    replica's in-flight requests are simply lost — no orphan
    re-dispatch, no re-solve. What static serving does."""

    def _redispatch(self, now):
        self._orphans.clear()      # lost with the replica


def run() -> None:
    pool = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    res = genetic.search(pool, prof, task, deadline=KILL_DEADLINE,
                         rate=KILL_RATE, iters=15, seed=0)
    plan = res.plan
    models = _models(pool, plan.assignment, prof, task)
    emit("chaos/incumbent", 0.0,
         f"att={res.attainment:.2f} replicas={plan.num_replicas}")

    # ---- replica kill mid-request --------------------------------------
    victim = max(range(len(models)),
                 key=lambda i: 1.0 / models[i].bottleneck)   # biggest server
    t_kill = KILL_DURATION / 3.0
    arr = slo_sim.poisson_arrivals(KILL_RATE, KILL_DURATION, seed=3)

    s_static = _run(_workers(models, plan.assignment), arr, KILL_DEADLINE,
                    _StaticKiller(kills=[(t_kill, victim)]))

    def _resolver(sig, ctl, now):
        if sig.kind != "replica_death":
            return None
        dead = sorted(d for key in sig.dead for d in key)
        t0 = time.monotonic()
        res2, _ = warm_resolve(pool, prof, task, incumbent=plan,
                               deadline=KILL_DEADLINE, rate=KILL_RATE,
                               dead_devices=dead, iters=6, seed=1)
        _resolver.resolve_s = time.monotonic() - t0
        pool2, _ = drop_devices(pool, dead)
        m2 = _models(pool2, res2.plan.assignment, prof, task)
        return {"workers": _workers(m2, res2.plan.assignment,
                                    start_id=100)}

    _resolver.resolve_s = 0.0
    # rate-only detector: the analytic requests carry empty prompts, so
    # prompt-mix detection stays off (the mix scenario feeds it directly)
    ctl = OnlineRescheduler(
        kills=[(t_kill, victim)],
        detector=DriftDetector(rate=KILL_RATE),
        resolver=_resolver)
    s_online = _run(_workers(models, plan.assignment), arr, KILL_DEADLINE,
                    ctl)
    emit("chaos/kill", _resolver.resolve_s * 1e6,
         f"static={s_static.attainment:.2f} (drop={s_static.dropped}) "
         f"online={s_online.attainment:.2f} "
         f"(redisp={ctl.redispatches}, re-solve="
         f"{_resolver.resolve_s:.1f}s)")
    emit_json("chaos.jsonl", "chaos/kill", {
        "attainment_static": round(s_static.attainment, 4),
        "attainment_online": round(s_online.attainment, 4),
        "dropped_static": s_static.dropped,
        "dropped_online": s_online.dropped,
        "redispatches": ctl.redispatches,
        "resolve_seconds": round(_resolver.resolve_s, 2)})

    # ---- 10x arrival spike ---------------------------------------------
    res = genetic.search(pool, prof, task, deadline=SPIKE_DEADLINE,
                         rate=SPIKE_RATE, iters=15, seed=0)
    plan_s = res.plan
    models_s = _models(pool, plan_s.assignment, prof, task)
    legs = [(SPIKE_RATE, 10.0), (10 * SPIKE_RATE, 8.0), (SPIKE_RATE, 30.0)]
    arr = slo_sim.piecewise_poisson_arrivals(legs, seed=5)
    s_static = _run(_workers(models_s, plan_s.assignment), arr,
                    SPIKE_DEADLINE)

    spike_stats = {}

    def _spike_resolver(sig, ctl, now):
        if sig.kind != "rate_spike" or spike_stats:
            return None            # re-solve once per sustained shift
        obs = sig.observed_rate
        res2, _ = warm_resolve(pool, prof, task, incumbent=plan_s,
                               deadline=SPIKE_DEADLINE, rate=obs,
                               iters=8, seed=1)
        # score both layouts at the SUSTAINED observed rate: the layout
        # that keeps up there is the one that drains the backlog
        m2 = _models(pool, res2.plan.assignment, prof, task)
        att_inc = slo_sim.simulate(models_s, obs, SPIKE_DEADLINE)
        att_new = slo_sim.simulate(m2, obs, SPIKE_DEADLINE)
        spike_stats.update(observed_rate=obs, att_incumbent=att_inc,
                           att_resolved=att_new)
        if att_new <= att_inc:
            return None            # incumbent still best under the spike
        return {"workers": _workers(m2, res2.plan.assignment,
                                    start_id=100)}

    ctl = OnlineRescheduler(
        detector=DriftDetector(rate=SPIKE_RATE),
        resolver=_spike_resolver)
    s_online = _run(_workers(models_s, plan_s.assignment), arr,
                    SPIKE_DEADLINE, ctl)
    emit("chaos/spike", 0.0,
         f"static={s_static.attainment:.2f} "
         f"online={s_online.attainment:.2f} "
         f"obs_rate={spike_stats.get('observed_rate', 0):.1f}/s "
         f"plan: {spike_stats.get('att_incumbent', 0):.2f}"
         f"->{spike_stats.get('att_resolved', 0):.2f}")
    emit_json("chaos.jsonl", "chaos/spike", {
        "attainment_static": round(s_static.attainment, 4),
        "attainment_online": round(s_online.attainment, 4),
        "observed_rate": round(spike_stats.get("observed_rate", 0.0), 2),
        "plan_att_incumbent": round(spike_stats.get("att_incumbent", 0.0),
                                    4),
        "plan_att_resolved": round(spike_stats.get("att_resolved", 0.0),
                                   4)})

    # ---- prompt-length mix shift (plan level) --------------------------
    task_long = cm.Task(batch=1, s_in=4 * task.s_in, s_out=task.s_out)
    det = DriftDetector(rate=KILL_RATE, prompt_len=task.s_in)
    sig = None
    for i in range(12):            # long prompts arriving at the old rate
        det.observe_admit(i / KILL_RATE, task_long.s_in)
        sig = sig or det.poll(i / KILL_RATE)
    assert sig is not None and sig.kind == "mix_shift", sig
    models_long = _models(pool, plan.assignment, prof, task_long)
    att_inc = slo_sim.simulate(models_long, KILL_RATE, KILL_DEADLINE)
    res2, _ = warm_resolve(pool, prof, task_long, incumbent=plan,
                           deadline=KILL_DEADLINE, rate=KILL_RATE,
                           iters=8, seed=1)
    emit("chaos/mix", 0.0,
         f"detector={sig.kind}(x{sig.factor:.1f}) "
         f"incumbent@4x={att_inc:.2f} resolved={res2.attainment:.2f}")
    emit_json("chaos.jsonl", "chaos/mix", {
        "detector_kind": sig.kind,
        "detector_factor": round(sig.factor, 2),
        "attainment_incumbent": round(att_inc, 4),
        "attainment_resolved": round(res2.attainment, 4)})


if __name__ == "__main__":
    run()
