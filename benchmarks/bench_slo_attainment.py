"""Paper Fig. 2 — cost-performance trade-off: SLO attainment of HexGen
(hetero full-price, w/ and w/o asymmetric parallelism, half-price) vs the
homogeneous A100 datacenter baseline, across SLO scales and request rates.

SLO scale is measured in multiples of the homogeneous A100 single-request
latency, exactly as in the paper; workloads are Poisson; the analytical cost
model provides per-replica latency/bottleneck and the discrete-event
simulator produces attainment."""
from __future__ import annotations

from typing import List

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.dp_layout import TP_CANDIDATES, optimize_pipeline
from repro.core.scheduler import schedule

OUT_LENS = (32, 64)
RATES = (0.5, 1.0, 2.0, 4.0, 8.0)
SLO_SCALES = (1.0, 2.0, 5.0, 10.0)


def _a100_unit_latency(task) -> float:
    homo = cl.homogeneous_a100()
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    plan = optimize_pipeline(homo, list(range(8)), prof, task)
    return plan.cost


def _symmetric_layout(cluster, device_ids, prof, task):
    """'HexGen w/o asymmetric parallelism' ablation: the same scheduled
    group, but executed the way FlashAttention/FasterTransformer require --
    every stage has the SAME TP degree and the SAME layer count (even
    split), no per-stage DP or memory-proportional EM. Cross-machine TP is
    permitted (that is exactly what hurts). Returns the best uniform plan or
    None (OOM at every uniform degree -- asymmetric support is what makes
    the group usable at all)."""
    ids = sorted(device_ids)
    best = None
    L = prof.num_layers
    for tp in (8, 4, 2, 1):
        n_stage = len(ids) // tp
        if n_stage == 0:
            continue
        stages = [ids[i * tp:(i + 1) * tp] for i in range(n_stage)]
        base = L // n_stage
        split = [base + (1 if j < L % n_stage else 0)
                 for j in range(n_stage)]
        cost = cm.pipeline_cost(cluster, stages, split, prof, task)
        if cost == float("inf"):
            continue
        bott = cm.pipeline_bottleneck(cluster, stages, split, prof, task)
        if best is None or cost < best[0]:
            best = (cost, bott)
    return best


def _replicas(cluster, task, *, symmetric_only=False, iters=12, seed=0):
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    from repro.core import genetic
    res = genetic.search(cluster, prof, task, deadline=10.0, rate=2.0,
                         iters=iters, seed=seed,
                         mutation="hexgen")
    reps = []
    for p in res.assignment.pipelines:
        if symmetric_only:
            got = _symmetric_layout(cluster, p.device_ids, prof, task)
            if got is None:
                continue
            reps.append(slo_sim.ReplicaModel(got[0], got[1]))
        else:
            reps.append(slo_sim.ReplicaModel(p.cost, p.bottleneck))
    return reps


def run(fast: bool = True) -> None:
    for out_len in OUT_LENS if not fast else OUT_LENS[:1]:
        task = cm.Task(batch=1, s_in=128, s_out=out_len)
        unit = _a100_unit_latency(task)
        settings = {
            "homogeneous_a100": _replicas(cl.homogeneous_a100(), task),
            "hexgen_full": _replicas(cl.hetero_full_price(), task),
            "hexgen_full_symmetric": _replicas(cl.hetero_full_price(), task,
                                               symmetric_only=True),
            "hexgen_half": _replicas(cl.hetero_half_price(), task),
        }
        for name, reps in settings.items():
            for scale in SLO_SCALES:
                att = [slo_sim.simulate(reps, r, scale * unit, duration=60.0)
                       for r in RATES]
                emit(f"slo/{name}/out{out_len}/scale{scale:g}", 0.0,
                     "att@rates(" + "|".join(f"{r:g}" for r in RATES) + ")="
                     + "|".join(f"{a:.2f}" for a in att))
            peak = slo_sim.peak_rate_for_attainment(reps, 5 * unit,
                                                    target=0.9, duration=60.0)
            mind = slo_sim.min_deadline_for_attainment(reps, 1.0, target=0.99,
                                                       duration=60.0)
            emit(f"slo/{name}/out{out_len}/summary", 0.0,
                 f"peak_rate@5xSLO={peak:.2f}req/s "
                 f"min_deadline@1req/s={mind:.2f}s unit={unit:.2f}s")


if __name__ == "__main__":
    run(fast=False)
