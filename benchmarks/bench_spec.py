"""Speculative decoding vs plain greedy decode on the paged 2-stage
pipeline (the PR-5 subsystem: serving.spec + multi-token verification).

Workload: self-repetitive prompts (a short pattern tiled) with moderately
long outputs — the high-acceptance regime where a proposer's guesses
track the target's greedy chain. Two proposers run against the same
baseline:

  * n-gram / prompt-lookup (weight-free): acceptance comes from the
    generation echoing its own context (its measured rate wobbles a few
    points across processes — the random-init model's near-flat logits
    make argmax tie-sensitive to run-to-run float reduction order;
    within a process the token-identity asserts always hold);
  * draft model (here the target itself as its own draft): acceptance
    saturates at 100%, the UPPER BOUND a well-distilled draft
    approaches, so every target step commits the full k + 1 tokens.

Tokens are asserted identical to baseline in every run (speculation
changes HOW MANY target steps a generation takes, never which tokens it
produces). The acceptance bar is >= 2x fewer target-model decode steps
for the draft run; the n-gram run rides along as the zero-weight
deployment point. Latency is measured on the virtual clock where every
target step costs one iteration — exactly the regime of a decode-bound
(slow) replica, the scheduler's motivation for deepening spec-k there.

Rows land in results/spec.jsonl.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request
from repro.serving.spec import SpecConfig

PATTERN = 4                  # tiled pattern length
PROMPT_LEN = 20
OUT_LEN = 24
MAX_LEN = 64
BLOCK = 8
SPEC_K = 5


def _workload(cfg, n=6):
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(n):
        pat = rng.randint(0, cfg.vocab_size, size=PATTERN).astype(np.int32)
        prompt = np.tile(pat, PROMPT_LEN)[:PROMPT_LEN + (i % 3)]
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=OUT_LEN,
                            arrival=0.5 * i))
    return reqs


def _serve(pipe_fn, reqs, spec=None):
    eng = PagedPipelineBatcher(pipe_fn(), n_slots=4, max_len=MAX_LEN,
                               block_size=BLOCK, spec=spec)
    stats = run_serve_loop([eng], reqs, deadline=1e9, clock=VirtualClock())
    p50 = float(np.percentile([r.latency for r in reqs], 50))
    return stats, p50


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    reqs_base = _workload(cfg)
    st_b, p50_b = _serve(pipe, reqs_base)
    total_tokens = sum(len(r.output) for r in reqs_base)
    emit("spec/baseline", 0.0,
         f"tokens={total_tokens} decode_steps={total_tokens} "
         f"iters={st_b.iterations} p50={p50_b:.2f}")

    rows = {}
    for name, spec in (
            ("ngram", SpecConfig(k=SPEC_K, proposer="ngram")),
            ("draft", SpecConfig(k=SPEC_K, proposer="draft", draft_cfg=cfg,
                                 draft_params=params))):
        reqs_s = _workload(cfg)
        st_s, p50_s = _serve(pipe, reqs_s, spec=spec)
        for rb, rs in zip(reqs_base, reqs_s):      # tokens unchanged, ever
            assert list(rb.output) == list(rs.output), rb.rid
        acc = st_s.spec_accepted / max(st_s.spec_proposed, 1)
        # baseline greedy decode spends exactly one target step per token
        ratio = total_tokens / st_s.spec_steps
        rows[name] = (st_s, p50_s, acc, ratio)
        emit(f"spec/{name}", 0.0,
             f"steps={st_s.spec_steps} ({ratio:.2f}x fewer) "
             f"acc={acc * 100:.0f}% p50={p50_s:.2f} "
             f"iters={st_s.iterations}")
        emit_json("spec.jsonl", f"spec_{name}", {
            "arch": cfg.name, "proposer": name, "spec_k": SPEC_K,
            "n_requests": len(reqs_base), "out_len": OUT_LEN,
            "block_size": BLOCK,
            "tokens": total_tokens,
            "baseline_decode_steps": total_tokens,
            "spec_target_steps": st_s.spec_steps,
            "step_reduction_x": float(ratio),
            "acceptance": float(acc),
            "proposed": st_s.spec_proposed,
            "accepted": st_s.spec_accepted,
            "base_p50_latency": p50_b, "spec_p50_latency": p50_s,
            "base_iterations": st_b.iterations,
            "spec_iterations": st_s.iterations,
        })

    _, p50_d, acc_d, ratio_d = rows["draft"]
    emit("spec/gain", 0.0,
         f"{ratio_d:.2f}x fewer target decode steps at "
         f"{acc_d * 100:.0f}% acceptance; p50 latency "
         f"{p50_b:.2f} -> {p50_d:.2f} virtual iters")
    assert ratio_d >= 2.0, \
        f"acceptance: >= 2x fewer target decode steps, got {ratio_d:.2f}x"
    assert p50_d < p50_b, \
        f"acceptance: spec p50 must beat baseline ({p50_d} vs {p50_b})"


if __name__ == "__main__":
    run()
