"""Deliverable (g): the roofline table, aggregated from the dry-run sweep
records (results/dryrun/*.json). One row per (arch x shape x mesh):
all three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, memory."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records(d=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(d or DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs):
    """Markdown rows for EXPERIMENTS.md §Roofline (single-pod only)."""
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MF/HLO | bytes/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("multi_pod") or "skipped" in r:
            continue
        rl = r["roofline"]
        uf = rl.get("useful_flops_frac")
        mem = r.get("memory_analysis", {})
        tot = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
        lines.append(
            f"| {r['arch']} | {r['shape']}"
            f"{' (swa)' if r.get('swa_variant') else ''} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {rl['dominant']} "
            f"| {uf:.3f} | {tot/1e9:.1f}G |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - |")
    return "\n".join(lines)


def run() -> None:
    dirs = [("baseline", DRYRUN_DIR)]
    if os.path.isdir("results/dryrun_opt") and \
            DRYRUN_DIR != "results/dryrun_opt":
        dirs.append(("optimized", "results/dryrun_opt"))
    for label, d in dirs:
        recs = load_records(d)
        if not recs:
            continue
        n_ok = sum(1 for r in recs if "skipped" not in r)
        n_skip = sum(1 for r in recs if "skipped" in r)
        emit(f"dryrun/{label}/summary", 0.0,
             f"compiled={n_ok} skipped={n_skip}")
        for r in recs:
            tag = (f"{label}/{r['arch']}/{r['shape']}/"
                   f"{'multi' if r['multi_pod'] else 'single'}")
            if "skipped" in r:
                emit(f"dryrun/{tag}", 0.0, "SKIP " + r["skipped"])
                continue
            rl = r["roofline"]
            uf = rl.get("useful_flops_frac") or 0.0
            emit(f"dryrun/{tag}", r["compile_s"] * 1e6,
                 f"compute={rl['compute_s']:.4f}s "
                 f"memory={rl['memory_s']:.4f}s "
                 f"coll={rl['collective_s']:.4f}s dom={rl['dominant']} "
                 f"mf_ratio={uf:.3f}")


if __name__ == "__main__":
    run()
    print()
    print(markdown_table(load_records()))
