"""Copy-on-write prefix caching vs the PR-2 paged baseline on a
shared-system-prompt workload.

Every prompt is `SHARED_LEN` tokens of system prompt (>= 50% of the
prompt) plus a short unique user tail — the multi-user regime the ROADMAP
north-star names, where prefill cost is dominated by re-computing the same
prefix for every request. Prefix caching aliases the resident prefix
blocks (refcount++) and prefills only the cold tail, so:

  * prefill tokens collapse to first-toucher + tails (the acceptance bar
    is >= 2x reduction);
  * TTFT drops, measured on the virtual clock with `prefill_token_cost`
    charging each prefilled token a fraction of an iteration — both
    engines pay the same per-token rate, so the delta is pure dedup.

Rows land in results/prefix.jsonl.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import shared_prefix_workload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

SHARED_LEN = 48              # system prompt, 6 whole blocks of 8
UNIQUE_LEN = 8               # user tail (jitter up to +4)
OUT_LEN = 8
MAX_LEN = 72
BLOCK = 8
TOKEN_COST = 0.125           # virtual iteration fraction per prefill token


def _emit_json(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = json.dumps({"bench": name, **payload}, sort_keys=True)
    with open(os.path.join(RESULTS_DIR, "prefix.jsonl"), "a") as f:
        f.write(row + "\n")
    print("# json: " + row)


def _workload(cfg):
    return shared_prefix_workload(
        rate=0.5, duration=30.0, vocab=cfg.vocab_size,
        shared_len=SHARED_LEN, unique_len=UNIQUE_LEN, unique_jitter=4,
        out_len=OUT_LEN, seed=7)


def _serve(pipe_fn, reqs, **kw):
    eng = PagedPipelineBatcher(pipe_fn(), n_slots=4, max_len=MAX_LEN,
                               block_size=BLOCK,
                               prefill_token_cost=TOKEN_COST, **kw)
    stats = run_serve_loop([eng], reqs, deadline=1e9, clock=VirtualClock())
    ttft = [r.first_token_time - r.arrival for r in reqs
            if r.first_token_time is not None]
    return stats, float(np.percentile(ttft, 50)), float(
        np.percentile(ttft, 99))


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    reqs_base = _workload(cfg)
    st_b, p50_b, p99_b = _serve(pipe, reqs_base)          # PR-2 paged
    reqs_warm = _workload(cfg)
    st_w, p50_w, p99_w = _serve(pipe, reqs_warm, prefix_caching=True)

    for rb, rw in zip(reqs_base, reqs_warm):              # tokens unchanged
        assert list(rb.output) == list(rw.output), rb.rid

    shared_frac = SHARED_LEN / float(np.mean(
        [len(r.prompt) for r in reqs_base]))
    reduction = st_b.prefill_tokens / max(st_w.prefill_tokens, 1)
    hit_rate = st_w.prefix_hits / max(st_w.prefix_lookups, 1)
    emit("prefix/baseline", 0.0,
         f"prefill={st_b.prefill_tokens}tok p50_ttft={p50_b:.2f} "
         f"p99_ttft={p99_b:.2f} iters={st_b.iterations}")
    emit("prefix/warm", 0.0,
         f"prefill={st_w.prefill_tokens}tok p50_ttft={p50_w:.2f} "
         f"p99_ttft={p99_w:.2f} hit={hit_rate * 100:.0f}% "
         f"saved={st_w.prefix_hit_tokens}tok cow={st_w.cow_copies}")
    emit("prefix/gain", 0.0,
         f"{reduction:.2f}x fewer prefill tokens, "
         f"p50 TTFT {p50_b:.2f} -> {p50_w:.2f} virtual iters "
         f"on a {shared_frac * 100:.0f}%-shared workload")
    _emit_json("prefix_vs_paged", {
        "arch": cfg.name, "n_requests": len(reqs_base),
        "shared_len": SHARED_LEN, "shared_frac": shared_frac,
        "block_size": BLOCK, "prefill_token_cost": TOKEN_COST,
        "base_prefill_tokens": st_b.prefill_tokens,
        "warm_prefill_tokens": st_w.prefill_tokens,
        "prefill_reduction_x": float(reduction),
        "hit_rate": float(hit_rate),
        "hit_tokens": st_w.prefix_hit_tokens,
        "cow_copies": st_w.cow_copies,
        "base_p50_ttft": p50_b, "warm_p50_ttft": p50_w,
        "base_p99_ttft": p99_b, "warm_p99_ttft": p99_w,
    })

    # chunked prefill rider: same workload, long prompts sliced to 16-token
    # chunks — fairness knob, outputs still identical
    reqs_chunk = _workload(cfg)
    st_c, p50_c, _ = _serve(pipe, reqs_chunk, prefix_caching=True,
                            prefill_chunk=16)
    for rb, rc in zip(reqs_base, reqs_chunk):
        assert list(rb.output) == list(rc.output), rb.rid
    emit("prefix/warm_chunked", 0.0,
         f"prefill={st_c.prefill_tokens}tok p50_ttft={p50_c:.2f} "
         f"iters={st_c.iterations}")
    _emit_json("prefix_chunked", {
        "arch": cfg.name, "prefill_chunk": 16,
        "prefill_tokens": st_c.prefill_tokens, "p50_ttft": p50_c,
        "iterations": st_c.iterations,
    })

    assert shared_frac >= 0.5, "workload must be >= 50% shared prefix"
    assert reduction >= 2.0, \
        f"acceptance: >= 2x prefill-token reduction, got {reduction:.2f}x"
    assert p50_w < p50_b, \
        f"acceptance: warm p50 TTFT must beat baseline ({p50_w} vs {p50_b})"


if __name__ == "__main__":
    run()
