"""Beyond-paper: continuous vs static batching under a bursty workload
(the paper's Appendix-D limitation). Same replica, same requests; latency
comes from the measured CPU engine (relative numbers are what matter)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.models import model as M
from repro.serving.continuous import ContinuousBatcher
from repro.serving.engine import InferenceEngine
from repro.serving.request import synth_workload


def run() -> None:
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def workload(seed):
        return synth_workload(rate=12.0, duration=1.0, vocab=cfg.vocab_size,
                              prompt_len=8, prompt_jitter=6, out_len=6,
                              seed=seed)

    # static batching (the paper's engine)
    asg = Assignment([PipelinePlan([StagePlan([0], cfg.num_layers)],
                                   cost=0.1, bottleneck=0.1)])
    eng = InferenceEngine(cfg, asg, params=params, max_batch=4)
    st = eng.serve(workload(3), deadline=60.0)
    emit("continuous/static", np.mean(st.latencies) * 1e6,
         f"p50={np.percentile(st.latencies, 50):.2f}s thpt={st.throughput:.2f}")

    cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=64)
    ct = cb.serve(workload(3), deadline=60.0, realtime=True)
    emit("continuous/continuous", np.mean(ct.latencies) * 1e6,
         f"p50={np.percentile(ct.latencies, 50):.2f}s thpt={ct.throughput:.2f}")
    emit("continuous/latency_gain", 0.0,
         f"{np.mean(st.latencies)/np.mean(ct.latencies):.2f}x lower mean latency")


if __name__ == "__main__":
    run()
