"""Beyond-paper: continuous vs static batching under a bursty workload
(the paper's Appendix-D limitation). Same replicas, same requests; latency
comes from the measured CPU engine (relative numbers are what matter).

Two comparisons:
  * single monolithic replica (the original beyond-paper extension);
  * a MULTI-STAGE asymmetric pipeline replica — the paper's actual
    artifact — served statically vs at iteration granularity through the
    shared loop. A JSON row records this path so the perf trajectory
    tracks it across PRs.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.models import model as M
from repro.serving.continuous import ContinuousBatcher
from repro.serving.engine import InferenceEngine
from repro.serving.request import synth_workload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _emit_json(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = json.dumps({"bench": name, **payload}, sort_keys=True)
    with open(os.path.join(RESULTS_DIR, "continuous.jsonl"), "a") as f:
        f.write(row + "\n")
    print("# json: " + row)


def run() -> None:
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def workload(seed):
        return synth_workload(rate=12.0, duration=1.0, vocab=cfg.vocab_size,
                              prompt_len=8, prompt_jitter=6, out_len=6,
                              seed=seed)

    # ---- monolithic replica: static engine vs slot batcher ---------------
    asg = Assignment([PipelinePlan([StagePlan([0], cfg.num_layers)],
                                   cost=0.1, bottleneck=0.1)])
    eng = InferenceEngine(cfg, asg, params=params, max_batch=4,
                          policy="static")
    st = eng.serve(workload(3), deadline=60.0)
    emit("continuous/static", np.mean(st.latencies) * 1e6,
         f"p50={np.percentile(st.latencies, 50):.2f}s thpt={st.throughput:.2f}")

    cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=64)
    ct = cb.serve(workload(3), deadline=60.0, realtime=True)
    emit("continuous/continuous", np.mean(ct.latencies) * 1e6,
         f"p50={np.percentile(ct.latencies, 50):.2f}s thpt={ct.throughput:.2f}")
    emit("continuous/latency_gain", 0.0,
         f"{np.mean(st.latencies)/np.mean(ct.latencies):.2f}x lower mean latency")

    # ---- multi-stage asymmetric replicas through the unified router ------
    L = cfg.num_layers
    split = [max(1, L // 3), L - max(1, L // 3)]
    asg2 = Assignment([
        PipelinePlan([StagePlan([0], split[0]), StagePlan([1], split[1])],
                     cost=0.1, bottleneck=0.1),
        PipelinePlan([StagePlan([2], L)], cost=0.1, bottleneck=0.1),
    ])
    results = {}
    for policy in ("static", "continuous"):
        eng = InferenceEngine(cfg, asg2, params=params, max_batch=4,
                              policy=policy, n_slots=4, max_len=64)
        # warm with the SAME workload as the measured pass (requests are
        # re-created fresh) so the timed run pays no unseen-shape compiles
        eng.serve(workload(5), deadline=60.0)
        stats = eng.serve(workload(5), deadline=60.0)
        results[policy] = stats
        emit(f"continuous/pipeline_{policy}",
             np.mean(stats.latencies) * 1e6,
             f"p50={np.percentile(stats.latencies, 50):.2f}s "
             f"thpt={stats.throughput:.2f} iters={stats.iterations}")
    gain = (np.mean(results["static"].latencies)
            / np.mean(results["continuous"].latencies))
    emit("continuous/pipeline_latency_gain", 0.0,
         f"{gain:.2f}x lower mean latency on 2-stage replicas")
    _emit_json("continuous_pipeline", {
        "arch": cfg.name, "stages": split, "replicas": 2,
        "static_mean_lat_s": float(np.mean(results["static"].latencies)),
        "static_p50_lat_s": float(
            np.percentile(results["static"].latencies, 50)),
        "static_thpt_rps": float(results["static"].throughput),
        "continuous_mean_lat_s": float(
            np.mean(results["continuous"].latencies)),
        "continuous_p50_lat_s": float(
            np.percentile(results["continuous"].latencies, 50)),
        "continuous_thpt_rps": float(results["continuous"].throughput),
        "latency_gain_x": float(gain),
    })


if __name__ == "__main__":
    run()
