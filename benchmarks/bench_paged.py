"""Paged vs contiguous KV cache under an EQUAL per-stage memory budget.

The contiguous slot engine reserves a full ``max_len`` cache row per slot,
so a fixed cache-token budget B caps concurrency at B / max_len regardless
of what requests actually use. The paged engine spends the same B tokens as
B / block_size blocks and admits by ACTUAL footprint (prompt + headroom,
growing one block per decoded token), so a mixed-length workload — mostly
short requests under a long-request ceiling — runs many more slots
concurrently and drains in fewer iterations.

Rows land in results/paged.jsonl; the acceptance bar is paged serving
>= 2x the concurrent slots of contiguous at equal memory.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# equal cache budget per stage, in tokens
BUDGET_TOKENS = 256
MAX_LEN = 64                 # per-request ceiling (the long tail must fit)
BLOCK = 8


def _emit_json(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = json.dumps({"bench": name, **payload}, sort_keys=True)
    with open(os.path.join(RESULTS_DIR, "paged.jsonl"), "a") as f:
        f.write(row + "\n")
    print("# json: " + row)


def _workload(cfg, *, n=24, seed=7):
    """Mixed lengths: mostly short chats, a few long documents — the regime
    where worst-case reservation wastes almost the whole pool."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        long = (i % 8 == 7)
        plen = int(rng.randint(24, 40)) if long else int(rng.randint(4, 10))
        out = 12 if long else 6
        reqs.append(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab_size,
                                      size=plen).astype(np.int32),
            max_new_tokens=out, arrival=0.0))
    return reqs


class _PeakConcurrency:
    """Wraps a slot engine to record the peak number of occupied slots."""

    def __init__(self, eng):
        self.eng = eng
        self.peak = 0

    def __getattr__(self, name):
        return getattr(self.eng, name)

    def run_iteration(self, now):
        out = self.eng.run_iteration(now)
        busy = sum(1 for s in self.eng.slots if not s.free)
        self.peak = max(self.peak, busy)
        return out


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    # contiguous: the budget buys BUDGET/MAX_LEN worst-case rows
    n_slots_contig = BUDGET_TOKENS // MAX_LEN
    contig = _PeakConcurrency(PipelineBatcher(
        pipe(), n_slots=n_slots_contig, max_len=MAX_LEN))
    st_c = run_serve_loop([contig], _workload(cfg), deadline=1e9,
                          clock=VirtualClock())

    # paged: the SAME budget buys BUDGET/BLOCK blocks; slots are bounded
    # only by bookkeeping, admission by actual prompt + headroom
    n_blocks = BUDGET_TOKENS // BLOCK + 1          # + reserved null block
    paged = _PeakConcurrency(PagedPipelineBatcher(
        pipe(), n_slots=16, max_len=MAX_LEN, block_size=BLOCK,
        stage_blocks=[n_blocks, n_blocks]))
    st_p = run_serve_loop([paged], _workload(cfg), deadline=1e9,
                          clock=VirtualClock())

    slots_gain = paged.peak / max(contig.peak, 1)
    thpt_gain = st_p.throughput / st_c.throughput
    emit("paged/contiguous_slots", 0.0,
         f"peak={contig.peak}/{n_slots_contig} thpt={st_c.throughput:.3f} "
         f"req/iter iters={st_c.iterations}")
    emit("paged/paged_slots", 0.0,
         f"peak={paged.peak}/16 thpt={st_p.throughput:.3f} req/iter "
         f"iters={st_p.iterations} preempt={st_p.preemptions}")
    emit("paged/gain", 0.0,
         f"{slots_gain:.2f}x concurrent slots, {thpt_gain:.2f}x throughput "
         f"at equal {BUDGET_TOKENS}-token budget")
    _emit_json("paged_vs_contiguous", {
        "arch": cfg.name, "budget_tokens": BUDGET_TOKENS,
        "max_len": MAX_LEN, "block_size": BLOCK,
        "contig_slots": n_slots_contig, "contig_peak": contig.peak,
        "contig_thpt_req_per_iter": float(st_c.throughput),
        "contig_iters": st_c.iterations,
        "paged_peak": paged.peak,
        "paged_thpt_req_per_iter": float(st_p.throughput),
        "paged_iters": st_p.iterations,
        "paged_preemptions": st_p.preemptions,
        "slots_gain_x": float(slots_gain),
        "throughput_gain_x": float(thpt_gain),
    })

    # asymmetric per-stage pools: a big-HBM stage 1 with a small stage 0
    # no longer drags concurrency down to the small peer's worst case —
    # stage pools are sized independently and admission takes the min
    asym = _PeakConcurrency(PagedPipelineBatcher(
        pipe(), n_slots=16, max_len=MAX_LEN, block_size=BLOCK,
        stage_blocks=[n_blocks // 2 + 1, 2 * n_blocks]))
    st_a = run_serve_loop([asym], _workload(cfg), deadline=1e9,
                          clock=VirtualClock())
    emit("paged/asymmetric_pools", 0.0,
         f"stage_blocks=[{n_blocks // 2 + 1},{2 * n_blocks}] "
         f"peak={asym.peak} thpt={st_a.throughput:.3f} req/iter "
         f"preempt={st_a.preemptions}")
    _emit_json("paged_asymmetric_pools", {
        "arch": cfg.name,
        "stage_blocks": [n_blocks // 2 + 1, 2 * n_blocks],
        "peak": asym.peak, "thpt_req_per_iter": float(st_a.throughput),
        "preemptions": st_a.preemptions,
    })

    assert slots_gain >= 2.0, \
        f"acceptance: paged should serve >=2x slots, got {slots_gain:.2f}x"


if __name__ == "__main__":
    run()
