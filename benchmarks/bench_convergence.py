"""Paper Fig. 6/7 — scheduler convergence: constrained mutations vs the
random-mutation strawman, plus the random-initialized allocation."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.scheduler import schedule


def run() -> None:
    task = cm.Task(batch=1, s_in=128, s_out=32)
    for name, pool, rate in (("full_price", cl.hetero_full_price(), 6.0),
                             ("half_price", cl.hetero_half_price(), 3.0)):
        hx = schedule(pool, "llama2-70b", task, deadline=10.0, rate=rate,
                      iters=20, seed=0, paper_exact=True)
        rnd = schedule(pool, "llama2-70b", task, deadline=10.0, rate=rate,
                       iters=20, seed=0, mutation="random", paper_exact=True)
        t_hx = hx.history[-1][0]
        emit(f"convergence/{name}/hexgen", t_hx * 1e6,
             f"att={hx.attainment:.2f} evals={hx.evaluations} "
             f"replicas={hx.assignment.num_replicas} "
             f"search_time={t_hx:.1f}s (paper: 2.1/1.5 min)")
        emit(f"convergence/{name}/random_mutation", rnd.history[-1][0] * 1e6,
             f"att={rnd.attainment:.2f} evals={rnd.evaluations}")
        init_att = hx.history[0][1]
        emit(f"convergence/{name}/random_init", 0.0,
             f"att={init_att:.2f} (Fig.7 baseline)")
        # convergence curve (best attainment over wall time)
        curve = "|".join(f"{t:.1f}:{a:.2f}" for t, a in hx.history[::4])
        emit(f"convergence/{name}/curve", 0.0, curve)


if __name__ == "__main__":
    run()
