"""Kernel microbenchmarks (CPU timings of the XLA paths; the Pallas TPU
kernels are validated in interpret mode -- their wall-clock here is Python
interpretation, not TPU performance, so we report the XLA path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def run() -> None:
    b, s, hq, hkv, d = 1, 1024, 8, 2, 64
    q = jax.random.normal(KEY, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(KEY, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(KEY, (b, s, hkv, d), jnp.float32)

    fa = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, q_block=256, kv_block=256))
    us = time_us(lambda: jax.block_until_ready(fa(q, k, v)))
    flops = 2 * b * hq * s * s * d * 2
    emit("kernels/flash_attention_1k", us,
         f"cpu_gflops={flops/us/1e3:.1f}")

    swa = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, window=256, q_block=256))
    us_swa = time_us(lambda: jax.block_until_ready(swa(q, k, v)))
    emit("kernels/swa_attention_1k_w256", us_swa,
         f"speedup_vs_full={us/us_swa:.2f}x")

    S = 8192
    qd = jax.random.normal(KEY, (4, 1, hq, d), jnp.float32)
    kc = jax.random.normal(KEY, (4, S, hkv, d), jnp.float32)
    vc = jax.random.normal(KEY, (4, S, hkv, d), jnp.float32)
    dec = jax.jit(lambda q, k, v: ops.decode_attention(q, k, v))
    us = time_us(lambda: jax.block_until_ready(dec(qd, kc, vc)))
    emit("kernels/decode_attention_8k", us,
         f"bytes={(kc.nbytes+vc.nbytes)/1e6:.0f}MB")

    din, ds = 256, 16
    x = jax.random.normal(KEY, (2, 2048, din), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(KEY, (2, 2048, din)))
    A = -jnp.exp(jax.random.normal(KEY, (din, ds)) * 0.5)
    B = jax.random.normal(KEY, (2, 2048, ds))
    C = jax.random.normal(KEY, (2, 2048, ds))
    D = jax.random.normal(KEY, (din,))
    scan = jax.jit(lambda *a: ops.ssm_scan(*a, chunk=128)[0])
    us = time_us(lambda: jax.block_until_ready(scan(x, dt, A, B, C, D)))
    emit("kernels/ssm_scan_2k", us, f"chunked(128)")

    # chunked-vs-sequential speedup (the chunk-parallel win)
    from repro.kernels import ref
    seq = jax.jit(lambda *a: ref.ssm_scan_ref(*a)[0])
    us_seq = time_us(lambda: jax.block_until_ready(seq(x, dt, A, B, C, D)))
    emit("kernels/ssm_scan_2k_sequential", us_seq,
         f"chunked_speedup={us_seq/us:.1f}x")


if __name__ == "__main__":
    run()
