"""Paper Fig. 3 — HexGen vs a Petals-style swarm baseline.

Petals model (documented simplification): swarm parallelism assigns each
model block to volunteer servers and routes every request through a chain
chosen dynamically; there is no topology-aware static schedule. We model it
as even-layer pipelines over round-robin device groups that ignore comm
topology (so stage hops regularly cross slow links), plus a per-stage
coordination overhead (DHT routing), on the same half-price pool.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.scheduler import schedule

SWARM_HOP_OVERHEAD = 0.02       # DHT/routing per stage hop (s)


def swarm_replicas(cluster, prof, task, stage_gpus: int = 8):
    """Topology-blind grouping into even single-GPU-stage pipelines.
    Fairness: servers are shuffled WITHIN each region (Petals prefers
    nearby peers), so groups are mostly intra-region but stage placement
    still ignores machine boundaries and memory asymmetry."""
    rng = np.random.default_rng(0)
    ids = []
    by_region = {}
    for d in cluster.devices:
        by_region.setdefault(d.region, []).append(d.id)
    for region in sorted(by_region):
        sub = by_region[region]
        rng.shuffle(sub)
        ids.extend(sub)
    reps = []
    per_replica = max(stage_gpus, 6)
    for i in range(0, len(ids) - per_replica + 1, per_replica):
        group = ids[i:i + per_replica]
        stages = [[d] for d in group]
        L = prof.num_layers
        split = [L // len(stages)] * len(stages)
        split[-1] += L - sum(split)
        cost = cm.pipeline_cost(cluster, stages, split, prof, task)
        if cost == float("inf"):
            continue
        cost += SWARM_HOP_OVERHEAD * len(stages)
        bott = cm.pipeline_bottleneck(cluster, stages, split, prof, task) \
            + SWARM_HOP_OVERHEAD
        reps.append(slo_sim.ReplicaModel(cost, bott))
    return reps


def run() -> None:
    half = cl.hetero_half_price()
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    for out_len in (32, 64):
        task = cm.Task(batch=1, s_in=128, s_out=out_len)
        res = schedule(half, "llama2-70b", task, deadline=10.0, rate=2.0,
                       iters=12, seed=0, paper_exact=True)
        hexgen = [slo_sim.ReplicaModel(p.cost, p.bottleneck)
                  for p in res.assignment.pipelines]
        swarm = swarm_replicas(half, prof, task)
        for name, reps in (("hexgen", hexgen), ("petals_swarm", swarm)):
            if not reps:
                emit(f"swarm/{name}/out{out_len}", 0.0, "infeasible")
                continue
            mind = slo_sim.min_deadline_for_attainment(
                reps, 1.0, target=0.99, duration=60.0)
            peak = slo_sim.peak_rate_for_attainment(
                reps, 20.0, target=0.9, duration=60.0)
            emit(f"swarm/{name}/out{out_len}", 0.0,
                 f"min_deadline={mind:.2f}s peak_rate={peak:.2f}req/s")
        if hexgen and swarm:
            d1 = slo_sim.min_deadline_for_attainment(hexgen, 1.0, 0.99,
                                                     duration=60.0)
            d2 = slo_sim.min_deadline_for_attainment(swarm, 1.0, 0.99,
                                                     duration=60.0)
            emit(f"swarm/advantage/out{out_len}", 0.0,
                 f"deadline_ratio={d2/d1:.1f}x (paper: up to 3.5x)")


if __name__ == "__main__":
    run()
