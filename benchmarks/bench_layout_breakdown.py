"""Paper Table 4 / Appendix F — scheduled layout breakdown by region for the
heterogeneous-full-price pool, plus replica-count comparison with the
homogeneous pool (paper: 16 A100 -> 4 replicas; 58 hetero GPUs -> ~12)."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.scheduler import schedule


# Paper Table 4 / Appendix F: the published full-price assignment.
# Device ids follow cluster.hetero_full_price() machine order.
TABLE4 = [
    # (stages as device-id lists)
    [[0, 1, 2, 3], [4, 5, 6, 7]],                 # Iceland 8x3090Ti [4,4]
    [[8, 9, 10, 11], [12, 13, 14, 15]],           # Iceland 8x3090Ti [4,4]
    [[16, 17], [18], [19], [20, 21]],             # Norway [2,1,1,2]
    [[22, 23, 24, 25], [26, 27, 28, 29]],         # Nevada A5000 [4,4]
    [[30, 31], [32]],                             # Illinois 3xA6000 [2,1]
    [[33, 34], [35]],
    [[38, 39], [40]],
    [[41, 42], [43]],
    [[36, 37], [46, 47]],                         # 2xA6000+2xA5000 [2,2]
    [[44, 45], [48, 49]],
    [[54, 55], [50, 51]],                         # 2xA40+2xA5000 [2,2]
    [[56, 57], [52, 53]],
]


def paper_table4_comparison(task):
    """Evaluate the published layout with asymmetric support vs the best
    symmetric (uniform-TP, even-split) execution of the same groups."""
    from repro.core import slo_sim
    from repro.core.dp_layout import _mem_proportional_split
    from benchmarks.bench_slo_attainment import _symmetric_layout
    full = cl.hetero_full_price()
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    asym, sym = [], []
    for stages in TABLE4:
        split = _mem_proportional_split(full, stages, prof.num_layers)
        cost = cm.pipeline_cost(full, stages, split, prof, task)
        if cost != float("inf"):
            asym.append(slo_sim.ReplicaModel(
                cost, cm.pipeline_bottleneck(full, stages, split, prof,
                                             task)))
        ids = [d for s in stages for d in s]
        got = _symmetric_layout(full, ids, prof, task)
        if got is not None:
            sym.append(slo_sim.ReplicaModel(*got))
    return asym, sym


def run() -> None:
    task = cm.Task(batch=1, s_in=128, s_out=32)
    from repro.core import slo_sim
    asym, sym = paper_table4_comparison(task)
    emit("layout/table4/replicas", 0.0,
         f"asym={len(asym)} sym={len(sym)} (paper: 12 replicas)")
    for name, reps in (("asym", asym), ("symmetric", sym)):
        if not reps:
            continue
        mind = slo_sim.min_deadline_for_attainment(reps, 1.0, 0.99,
                                                   duration=60.0)
        peak = slo_sim.peak_rate_for_attainment(reps, 10.0, 0.9,
                                                duration=60.0)
        emit(f"layout/table4/{name}", 0.0,
             f"min_deadline={mind:.2f}s peak_rate={peak:.2f}req/s "
             f"mean_lat={sum(r.latency for r in reps)/len(reps):.2f}s")
    if asym and sym:
        d1 = slo_sim.min_deadline_for_attainment(asym, 1.0, 0.99, duration=60.0)
        d2 = slo_sim.min_deadline_for_attainment(sym, 1.0, 0.99, duration=60.0)
        emit("layout/table4/asym_advantage", 0.0,
             f"deadline_ratio={d2/d1:.2f}x (paper: up to 1.8x)")
    full = cl.hetero_full_price()
    res = schedule(full, "llama2-70b", task, deadline=10.0, rate=8.0,
                   iters=25, seed=0, paper_exact=True)
    emit("layout/full_price/replicas", 0.0,
         f"{res.assignment.num_replicas} (paper: up to 12)")
    for i, p in enumerate(res.assignment.pipelines):
        regions = sorted({full.devices[d].region for d in p.device_ids})
        types = sorted({full.devices[d].type for d in p.device_ids})
        emit(f"layout/full_price/pipeline{i}", p.cost * 1e6,
             f"strategy={p.describe()} regions={'+'.join(regions)} "
             f"gpus={'+'.join(types)}")
    # structural properties the paper reports
    cross_region = 0
    for p in res.assignment.pipelines:
        regs = {full.devices[d].region for d in p.device_ids}
        if len(regs) > 1:
            cross_region += 1
    emit("layout/full_price/cross_region_pipelines", 0.0,
         f"{cross_region} (paper: scheduling avoids cross-region groups)")
    tp_cross_machine = 0
    for p in res.assignment.pipelines:
        for s in p.stages:
            if len({full.devices[d].machine for d in s.device_ids}) > 1:
                tp_cross_machine += 1
    emit("layout/full_price/tp_groups_cross_machine", 0.0,
         f"{tp_cross_machine} (paper heuristic: always 0)")

    homo = cl.homogeneous_a100()
    res_h = schedule(homo, "llama2-70b", task, deadline=10.0, rate=8.0,
                     iters=15, seed=0, paper_exact=True)
    emit("layout/homogeneous/replicas", 0.0,
         f"{res_h.assignment.num_replicas} (paper: 4)")


if __name__ == "__main__":
    run()
