"""Disaggregated prefill/decode vs colocated serving on a heterogeneous
two-replica pool (the HexGen-2 move on top of PR 2/3's paged engine).

Setup: one compute-rich replica (fast iterations) and one memory-rich but
SLOW replica (4x the per-iteration cost — an older, bigger-HBM GPU on the
virtual clock), serving a prefill-heavy workload (long prompts, short
outputs) with `prefill_token_cost` charging every prefilled token its
share of an iteration.

Colocated, the least-loaded router sends roughly half the arrivals to the
slow replica, which grinds through their long prefills at 4x cost — those
requests' TTFT explodes, and decode iterations on the same replica stall
behind every new prefill burst. Disaggregated, EVERY prefill runs on the
fast replica; the finished pages ship over the modeled link and only the
steady decode drip runs on the slow replica. TTFT collapses to
fast-prefill + transfer + one slow decode iteration, at the price of a
higher TPOT on the slow decoder — exactly the tradeoff the role scheduler
weighs. Tokens stay bit-identical both ways (asserted).

Rows land in results/disagg.jsonl; the acceptance bar is a real p50 TTFT
win for disaggregation.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.disagg import KVLink, wire_disaggregation
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import synth_workload

PROMPT_LEN = 48              # prefill-heavy: 6 blocks of prompt ...
OUT_LEN = 4                  # ... and a short answer
MAX_LEN = 64
BLOCK = 8
TOKEN_COST = 0.125           # iteration fraction per prefilled token
SLOW_FACTOR = 6.0            # the memory-rich replica's iteration cost
LINK_GBPS = 1e-3             # modeled KV link (virtual clock units)


def _workload(cfg):
    # rate chosen so ONE fast replica absorbs every prefill (utilization
    # < 1): the comparison isolates the slow replica's prefill latency and
    # the prefill/decode interference, not raw prefill capacity
    return synth_workload(rate=0.08, duration=200.0, vocab=cfg.vocab_size,
                          prompt_len=PROMPT_LEN, prompt_jitter=8,
                          out_len=OUT_LEN, seed=9)


def _percentiles(reqs):
    ttft = np.asarray([r.first_token_time - r.arrival for r in reqs])
    tpot = np.asarray([(r.finish_time - r.first_token_time)
                       / max(r.max_new_tokens - 1, 1) for r in reqs])
    return (float(np.percentile(ttft, 50)), float(np.percentile(ttft, 99)),
            float(np.mean(tpot)))


def _serve(pipes, roles, reqs):
    step_costs = [1.0, SLOW_FACTOR]
    workers = [PagedPipelineBatcher(
        p, n_slots=4, max_len=MAX_LEN, block_size=BLOCK,
        prefill_token_cost=TOKEN_COST, virtual_step_cost=sc,
        role=role, replica_id=i)
        for i, (p, role, sc) in enumerate(zip(pipes, roles, step_costs))]
    wire_disaggregation(workers, roles, KVLink(gbps=LINK_GBPS))
    stats = run_serve_loop(workers, reqs, deadline=1e9,
                           clock=VirtualClock())
    return stats


def run() -> None:
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipes():
        return [AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])
                for _ in range(2)]

    reqs_c = _workload(cfg)
    st_c = _serve(pipes(), ["both", "both"], reqs_c)
    p50_c, p99_c, tpot_c = _percentiles(reqs_c)

    reqs_d = _workload(cfg)
    st_d = _serve(pipes(), ["prefill", "decode"], reqs_d)
    p50_d, p99_d, tpot_d = _percentiles(reqs_d)

    for rc, rd in zip(reqs_c, reqs_d):       # tokens unchanged by the split
        assert list(rc.output) == list(rd.output), rc.rid

    gain = p50_c / p50_d
    emit("disagg/colocated", 0.0,
         f"p50_ttft={p50_c:.2f} p99_ttft={p99_c:.2f} "
         f"tpot={tpot_c:.2f} iters={st_c.iterations}")
    emit("disagg/disaggregated", 0.0,
         f"p50_ttft={p50_d:.2f} p99_ttft={p99_d:.2f} tpot={tpot_d:.2f} "
         f"mig={st_d.migrations} ({st_d.migrated_kv_bytes / 1e6:.2f}MB)")
    emit("disagg/gain", 0.0,
         f"{gain:.2f}x lower p50 TTFT on a prefill-heavy workload with a "
         f"{SLOW_FACTOR:.0f}x-slow decode replica "
         f"(TPOT {tpot_c:.2f} -> {tpot_d:.2f})")
    emit_json("disagg.jsonl", "disagg_vs_colocated", {
        "arch": cfg.name, "n_requests": len(reqs_c),
        "prompt_len": PROMPT_LEN, "out_len": OUT_LEN,
        "prefill_token_cost": TOKEN_COST, "slow_factor": SLOW_FACTOR,
        "kv_link_gbps": LINK_GBPS,
        "colocated_p50_ttft": p50_c, "colocated_p99_ttft": p99_c,
        "colocated_tpot": tpot_c,
        "disagg_p50_ttft": p50_d, "disagg_p99_ttft": p99_d,
        "disagg_tpot": tpot_d,
        "migrations": st_d.migrations,
        "migrated_kv_mb": st_d.migrated_kv_bytes / 1e6,
        "ttft_gain_x": gain,
    })

    assert gain > 1.0, \
        f"acceptance: disaggregation must cut p50 TTFT, got {gain:.2f}x"


if __name__ == "__main__":
    run()
