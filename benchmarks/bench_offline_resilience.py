"""Paper Fig. 4 — resilience: 4 GPUs leave the scheduled pool; HexGen
re-runs the (warm-started) search and should recover most attainment
quickly (paper: <30 s re-search, small performance gap)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import genetic
from repro.core.resched import warm_resolve


def run() -> None:
    pool = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    res = genetic.search(pool, prof, task, deadline=10.0, rate=3.0,
                         iters=15, seed=0)
    emit("offline/before", 0.0,
         f"att={res.attainment:.2f} replicas={res.assignment.num_replicas}")

    drop = list(range(4))                     # one half of an Iceland machine
    # core.resched's incremental path: project the incumbent onto the
    # surviving pool and run a short warm-started search from it
    t0 = time.monotonic()
    res2, _ = warm_resolve(pool, prof, task, incumbent=res.plan,
                           deadline=10.0, rate=3.0, dead_devices=drop,
                           iters=8, seed=1)
    dt = time.monotonic() - t0
    emit("offline/after_4gone", dt * 1e6,
         f"att={res2.attainment:.2f} replicas="
         f"{res2.assignment.num_replicas} re-search={dt:.1f}s "
         f"(paper: <30s, small gap)")


if __name__ == "__main__":
    run()
