"""Paper Fig. 4 — resilience: 4 GPUs leave the scheduled pool; HexGen
re-runs the (warm-started) search and should recover most attainment
quickly (paper: <30 s re-search, small performance gap)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import genetic, slo_sim
from repro.core.cluster import Cluster


def drop_devices(cluster: Cluster, drop):
    keep = [d for d in cluster.devices if d.id not in drop]
    remap = {d.id: i for i, d in enumerate(keep)}
    devs = [cl.Device(remap[d.id], d.type, d.machine, d.region) for d in keep]
    idx = [d.id for d in keep]
    return Cluster(devs, cluster.lat[np.ix_(idx, idx)],
                   cluster.bw[np.ix_(idx, idx)])


def run() -> None:
    pool = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    res = genetic.search(pool, prof, task, deadline=10.0, rate=3.0,
                         iters=15, seed=0)
    emit("offline/before", 0.0,
         f"att={res.attainment:.2f} replicas={res.assignment.num_replicas}")

    drop = set(list(range(4)))                # one half of an Iceland machine
    pool2 = drop_devices(pool, drop)
    # warm start: previous groups minus dropped devices
    warm = []
    remap = {d: i for i, d in enumerate(sorted(
        x for x in range(len(pool)) if x not in drop))}
    for p in res.assignment.pipelines:
        g = frozenset(remap[d] for d in p.device_ids if d not in drop)
        if g:
            warm.append(g)
    assigned = {d for g in warm for d in g}
    rest = frozenset(set(range(len(pool2))) - assigned)
    if rest:
        warm.append(rest)
    t0 = time.monotonic()
    res2 = genetic.search(pool2, prof, task, deadline=10.0, rate=3.0,
                          iters=8, seed=1, init=[tuple(warm)])
    dt = time.monotonic() - t0
    emit("offline/after_4gone", dt * 1e6,
         f"att={res2.attainment:.2f} replicas="
         f"{res2.assignment.num_replicas} re-search={dt:.1f}s "
         f"(paper: <30s, small gap)")


if __name__ == "__main__":
    run()
